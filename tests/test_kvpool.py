"""KVPool unit tests: allocation/refcount/registry lifecycle, LRU
eviction, backpressure, and a hypothesis sequence test asserting the
no-page-leak invariant across random submit/publish/retire interleavings.

These are pure host-side tests (no device, no jax) — the engine-level
token-identity tests for paged serving live in tests/test_serve.py.
"""

import pytest

from repro.serve.kvpool import KVPool, pages_for

P = 4   # page size used throughout


def _pool(num_pages=16, **kw):
    return KVPool(P, num_pages, **kw)


def _admit_publish(pool, row, prompt, max_new=4):
    """Admit + immediately publish the whole prompt (as the engine does
    once prefill has consumed it)."""
    got = pool.try_admit(row, prompt, len(prompt) + max_new - 1)
    assert got is not None
    pool.publish_upto(row, len(prompt))
    return got


def test_pages_for():
    assert pages_for(1, P) == 1
    assert pages_for(P, P) == 1
    assert pages_for(P + 1, P) == 2
    assert pages_for(10 * P, P) == 10


def test_constructor_validation():
    with pytest.raises(ValueError):
        KVPool(0, 4)
    with pytest.raises(ValueError):
        KVPool(4, 0)


def test_admit_release_round_trip():
    pool = _pool()
    got = pool.try_admit(0, [1, 2, 3], 3 + 4 - 1)    # 6 positions, 2 pages
    assert got is not None
    pages, reused = got
    assert len(pages) == 2 and reused == 0
    assert pool.stats()["free_pages"] == 14
    assert pool.row_pages(0) == pages
    pool.check_invariants()
    pool.release_row(0)          # nothing published: pages go back free
    assert pool.stats()["free_pages"] == 16
    assert pool.row_pages(0) == []
    pool.check_invariants()


def test_prefix_reuse_after_publication():
    pool = _pool()
    prompt = list(range(1, 12))                      # 11 tokens, 2 full pages
    (pages_a, reused_a) = _admit_publish(pool, 0, prompt)
    assert reused_a == 0
    pool.release_row(0)
    # published pages stay cached, NOT free
    assert pool.stats()["cached_pages"] == 2
    assert pool.stats()["free_pages"] == 16 - 2
    pool.check_invariants()

    (pages_b, reused_b) = _admit_publish(pool, 1, prompt)
    assert reused_b == 2 * P                          # both full pages hit
    assert pages_b[:2] == pages_a[:2]                 # same physical pages
    assert pool.hit_requests_total == 1
    assert pool.stats()["prefix_hit_rate"] == pytest.approx(0.5)
    pool.check_invariants()
    pool.release_row(1)
    pool.check_invariants()


def test_shared_page_never_freed_while_mapped():
    pool = _pool()
    prompt = list(range(1, 10))                       # 2 full pages
    _admit_publish(pool, 0, prompt)
    (pages_b, reused) = _admit_publish(pool, 1, prompt)
    assert reused == 2 * P
    shared = set(pages_b[:2])
    assert all(pool.ref[p] == 2 for p in shared)
    pool.release_row(0)
    # row 1 still maps the shared pages: refcount 1, not free, not cached
    assert all(pool.ref[p] == 1 for p in shared)
    assert not shared & set(pool.free)
    pool.check_invariants()
    pool.release_row(1)
    # now cached (registered, ref 0) — still not free
    assert not shared & set(pool.free)
    assert shared <= set(pool.key_of)
    pool.check_invariants()


def test_partial_pages_and_teacher_forcing_boundary_never_match():
    pool = _pool()
    _admit_publish(pool, 0, [1, 2, 3])                # < 1 full page
    assert pool.published_pages_total == 0
    pool.release_row(0)
    # an exactly-one-page prompt publishes nothing reusable either: its
    # last token must be teacher-forced, so the match limit is 0 pages
    _admit_publish(pool, 0, [1, 2, 3, 4])
    pool.release_row(0)
    got = pool.try_admit(1, [1, 2, 3, 4], 8)
    assert got is not None and got[1] == 0            # no reuse
    pool.release_row(1)
    pool.check_invariants()


def test_lru_eviction_under_pressure():
    pool = _pool(num_pages=4)
    _admit_publish(pool, 0, list(range(1, 10)))       # 3 pages, 2 published
    pool.release_row(0)                               # 2 cached, 3 free
    assert pool.stats()["cached_pages"] == 2
    # a 4-page request must evict both cached pages (LRU) to fit
    got = pool.try_admit(1, list(range(20, 33)), 13 + 4 - 1)
    assert got is not None and len(got[0]) == 4
    assert pool.stats()["cached_pages"] == 0
    assert pool.evicted_pages_total == 2
    assert pool.registry == {}                        # evicted = unregistered
    pool.check_invariants()
    pool.release_row(1)
    pool.check_invariants()


def test_matched_pages_survive_eviction_pressure():
    """An admit that both hits the prefix cache AND needs eviction must
    never evict the pages it just matched."""
    pool = _pool(num_pages=4)
    prompt = list(range(1, 10))                       # 3 pages, 2 published
    _admit_publish(pool, 0, prompt)
    pool.release_row(0)                               # 2 cached, 3 free
    # same prefix + long tail: needs 2 matched + 2 fresh pages, and only
    # 3 free — fine; matched pages stay pinned
    got = pool.try_admit(1, prompt + [99] * 4, 9 + 4 + 4 - 1)
    assert got is not None
    pages, reused = got
    assert reused == 2 * P
    assert pool.evicted_pages_total == 0
    pool.check_invariants()
    pool.release_row(1)
    pool.check_invariants()


def test_backpressure_mutates_nothing():
    pool = _pool(num_pages=2)
    assert pool.try_admit(0, list(range(1, 10)), 12) is None   # needs 3
    assert pool.stats()["free_pages"] == 2
    assert pool._rows == {} and pool._pending == {}
    pool.check_invariants()
    # after freeing capacity the same admit succeeds
    got = pool.try_admit(0, [1, 2], 2 + 4 - 1)
    assert got is not None
    pool.check_invariants()


def test_double_free_and_double_admit_raise():
    pool = _pool()
    pool.try_admit(0, [1, 2], 4)
    with pytest.raises(RuntimeError):
        pool.try_admit(0, [3, 4], 4)                  # row already mapped
    pool.release_row(0)
    pool.release_row(0)                               # empty row: no-op
    pool.try_admit(1, [1, 2], 4)
    pool._rows[2] = list(pool._rows[1])               # forge a double map
    pool.release_row(1)
    with pytest.raises(RuntimeError):
        pool.release_row(2)


def test_concurrent_publication_converges():
    """Two rows prefilling the same prompt concurrently (admitted before
    either published) converge on ONE physical chain: the second publisher
    chains through the first's pages, and a later request matches them."""
    pool = _pool()
    prompt = list(range(1, 10))                       # 2 full pages
    got_a = pool.try_admit(0, prompt, 12)
    got_b = pool.try_admit(1, prompt, 12)
    assert got_a[1] == 0 and got_b[1] == 0            # nothing published yet
    pool.publish_upto(0, len(prompt))
    pool.publish_upto(1, len(prompt))                 # loses both races
    assert pool.published_pages_total == 2            # one chain, not two
    got_c = pool.try_admit(2, prompt, 12)
    assert got_c[1] == 2 * P
    assert got_c[0][:2] == got_a[0][:2]               # the winner's pages
    pool.check_invariants()
    for r in (0, 1, 2):
        pool.release_row(r)
    pool.check_invariants()


def test_publication_waits_for_residency():
    pool = _pool()
    prompt = list(range(1, 10))
    pool.try_admit(0, prompt, 12)
    pool.publish_upto(0, P - 1)                       # page 0 not resident
    assert pool.published_pages_total == 0
    pool.publish_upto(0, P)                           # page 0 now resident
    assert pool.published_pages_total == 1
    pool.publish_upto(0, len(prompt))
    assert pool.published_pages_total == 2
    pool.check_invariants()


# ---------------------------------------------------------------------------
# Property test: no page leaks across random event interleavings.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    @st.composite
    def _events(draw):
        """A random interleaving of admit/publish/release events over a
        small prompt alphabet (so prefix collisions are common)."""
        n = draw(st.integers(3, 40))
        out = []
        for _ in range(n):
            kind = draw(st.sampled_from(["admit", "publish", "release"]))
            if kind == "admit":
                plen = draw(st.integers(1, 14))
                prompt = draw(st.lists(st.integers(1, 3), min_size=plen,
                                       max_size=plen))
                out.append(("admit", prompt, draw(st.integers(1, 6))))
            else:
                out.append((kind, draw(st.integers(0, 3))))
        return out


def test_no_page_leaks_across_interleavings():
    """After EVERY event: free + in_use + cached == num_pages, refcounts
    equal mapping rows, and no row's mapped page sits on the free list —
    the full check_invariants battery, over hypothesis-driven random
    event interleavings."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)")

    @settings(max_examples=60, deadline=None)
    @given(_events(), st.integers(4, 12))
    def run(events, num_pages):
        _check_interleaving(events, num_pages)

    run()


def _check_interleaving(events, num_pages):
    pool = KVPool(P, num_pages)
    slots = {}                        # row -> prompt_len (admitted rows)
    for ev in events:
        if ev[0] == "admit":
            _, prompt, max_new = ev
            row = next((r for r in range(4) if r not in slots), None)
            total = len(prompt) + max_new - 1
            if row is None or pages_for(total, P) > num_pages:
                continue
            got = pool.try_admit(row, prompt, total)
            if got is not None:
                pages, reused = got
                assert len(pages) == pages_for(total, P)
                assert reused <= max(0, len(prompt) - 1)
                assert reused % P == 0
                slots[row] = len(prompt)
        elif ev[0] == "publish":
            row = ev[1]
            if row in slots:
                # publish an arbitrary residency (engine only ever grows
                # it, but the pool must tolerate any partial point)
                pool.publish_upto(row, slots[row])
        else:
            row = ev[1]
            if row in slots:
                pool.release_row(row)
                del slots[row]
        pool.check_invariants()
    for row in list(slots):
        pool.release_row(row)
    pool.check_invariants()
    # with every row retired, nothing is in use: free + cached == all
    st_ = pool.stats()
    assert st_["in_use_pages"] == 0
    assert st_["free_pages"] + st_["cached_pages"] == num_pages
