"""Property-based tests for the system invariants added in the perf work:
the shard_map/gather-only MoE dispatch, the WKV recurrence, the RG-LRU
scan, and the distributed log-sum-exp combine used by vocab-parallel CCE.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.kernels import ref
from repro.models import layers as L
from repro.models import recurrent as R

jax.config.update("jax_platform_name", "cpu")

_SETTINGS = dict(max_examples=10, deadline=None)


# ---------------------------------------------------------------------------
# WKV recurrence: state composition (chunking must be associative).
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16), split=st.sampled_from([8, 16, 24]))
def test_wkv_state_composition(seed, split):
    """Running [0, split) then [split, S) with the carried state equals one
    full run — the invariant that makes chunked training and O(1)-state
    decode (long_500k) the same computation."""
    B, H, S, hd = 1, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, hd)) for i in range(3))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, H, S, hd)) - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    s0 = jnp.zeros((B, H, hd, hd))

    o_full, s_full = ref.ref_wkv(r, k, v, w_log, u, s0)
    o1, s_mid = ref.ref_wkv(r[:, :, :split], k[:, :, :split],
                            v[:, :, :split], w_log[:, :, :split], u, s0)
    o2, s_end = ref.ref_wkv(r[:, :, split:], k[:, :, split:],
                            v[:, :, split:], w_log[:, :, split:], u, s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 2)),
                               np.asarray(o_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([4, 8, 16]))
def test_wkv_chunked_equals_sequential(seed, chunk):
    B, H, S, hd = 1, 1, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, hd)) for i in range(3))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, H, S, hd)) - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    s0 = jnp.zeros((B, H, hd, hd))
    o_ref, s_ref = ref.ref_wkv(r, k, v, w_log, u, s0)
    o, sf = R._rwkv6_chunk(r, k, v, w_log, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(s_ref),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# MoE dispatch invariants.
# ---------------------------------------------------------------------------

def _moe_setup(seed, t=48, d=16, e=4, k=2, cap=8.0):
    cfg = MoEConfig(num_experts=e, top_k=k, d_ff_expert=24,
                    capacity_factor=cap)
    params = L.init_moe(jax.random.PRNGKey(seed), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, d)) * 0.5
    return cfg, params, x


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_moe_dispatch_token_permutation_equivariance(seed):
    """Routing is per-token: permuting the tokens permutes the outputs
    (with generous capacity so drop sets are permutation-independent)."""
    cfg, params, x = _moe_setup(seed)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 2), x.shape[0])
    out, _ = L._moe_gather_dispatch(x, params, cfg)
    out_p, _ = L._moe_gather_dispatch(x[perm], params, cfg)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out[perm]),
                               atol=1e-5, rtol=1e-5)


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_moe_dispatch_matches_dense_topk_oracle(seed):
    """With capacity >= T the dispatch equals the dense 'every expert on
    every token, combine top-k' oracle."""
    cfg, params, x = _moe_setup(seed)
    out, _ = L._moe_gather_dispatch(x, params, cfg)

    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    gate = jnp.einsum("td,edf->tef", x, params["w_gate"])
    up = jnp.einsum("td,edf->tef", x, params["w_up"])
    all_out = jnp.einsum("tef,efd->ted", jax.nn.silu(gate) * up,
                         params["w_down"])   # (T, E, d)
    dense = jnp.einsum("tk,tkd->td", top_p,
                       jnp.take_along_axis(
                           all_out, top_e[:, :, None], axis=1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=1e-4, rtol=1e-4)


def _scatter_dispatch_oracle(x, params, cfg):
    """The original scatter-based dispatch (plain jnp autodiff transpose)
    — ground truth for the gather-only custom VJPs, drops included."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = min(max(1, int(t * k * cfg.capacity_factor / e)), t)
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sp = flat_e[order], flat_t[order], top_p.reshape(-1)[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k) - starts[se]
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e * cap)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(x[st])
    h = buf[:-1].reshape(e, cap, d)
    gate = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                       params["w_down"]).reshape(e * cap, d)
    out_e = jnp.concatenate([out_e, jnp.zeros((1, d), out_e.dtype)], 0)
    contrib = out_e[dest] * (sp * keep).astype(out_e.dtype)[:, None]
    return jnp.zeros((t, d), x.dtype).at[st].add(contrib.astype(x.dtype))


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_moe_permutation_vjps_match_plain_autodiff(seed):
    """The gather-only custom VJPs must equal what plain jnp indexing
    autodiff (scatter-add transpose) produces — including under tight
    capacity with dropped tokens."""
    cfg, params, x = _moe_setup(seed, cap=1.2)   # tight capacity: with drops
    g = jax.random.normal(jax.random.PRNGKey(seed + 3), x.shape)

    def loss_new(x, params):
        out, _ = L._moe_gather_dispatch(x, params, cfg)
        return jnp.sum(out * g)

    def loss_ref(x, params):
        return jnp.sum(_scatter_dispatch_oracle(x, params, cfg) * g)

    gx_new, gp_new = jax.grad(loss_new, argnums=(0, 1))(x, params)
    gx_ref, gp_ref = jax.grad(loss_ref, argnums=(0, 1))(x, params)
    np.testing.assert_allclose(np.asarray(gx_new), np.asarray(gx_ref),
                               atol=1e-5, rtol=1e-5)
    for key in ("w_gate", "w_up", "w_down", "router"):
        np.testing.assert_allclose(np.asarray(gp_new[key]),
                                   np.asarray(gp_ref[key]),
                                   atol=1e-5, rtol=1e-5, err_msg=key)


# ---------------------------------------------------------------------------
# Distributed LSE combine (the vocab-parallel CCE reduction).
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16), shards=st.sampled_from([2, 4, 8]))
def test_sharded_logsumexp_combine(seed, shards):
    """lse = m + log(sum_i exp(lse_i - m)) over arbitrary vocab splits —
    the exact combine vocab_parallel uses across the model axis."""
    n, v = 16, 64
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, v)) * 3.0
    full = jax.scipy.special.logsumexp(a, axis=1)
    parts = jnp.stack([jax.scipy.special.logsumexp(p, axis=1)
                       for p in jnp.split(a, shards, axis=1)])
    m = jnp.max(parts, axis=0)
    combined = m + jnp.log(jnp.sum(jnp.exp(parts - m), axis=0))
    np.testing.assert_allclose(np.asarray(combined), np.asarray(full),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RG-LRU: associative scan equals the sequential recurrence.
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_rglru_scan_matches_sequential(seed):
    B, S, W = 2, 24, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    xt = jax.random.normal(ks[0], (B, S, W))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W)))  # decay in (0,1)
    h_scan = R._rglru_scan(xt, a)
    h = jnp.zeros((B, W))
    hs = []
    for t in range(S):
        h = a[:, t] * h + jnp.sqrt(jnp.maximum(1 - a[:, t] ** 2, 1e-12)) \
            * xt[:, t]
        hs.append(h)
    np.testing.assert_allclose(np.asarray(h_scan),
                               np.asarray(jnp.stack(hs, 1)),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Fused single-pass CCE backward + fwd-emitted block-sparsity maps
# (DESIGN.md §7) — interpret-mode property tests.
# ---------------------------------------------------------------------------

from repro.kernels import CCEConfig, cce_fwd, linear_cross_entropy_pallas
from repro.kernels.cce_bwd import DEFAULT_FILTER_EPS


def _cce_problem(seed, n, d, v, peaked):
    if peaked:
        return ref.peaked_problem(n, d, v, hot=max(v // 8, 1), seed=seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    C = jax.random.normal(ks[0], (v, d)) * (d ** -0.5)
    x = jax.random.randint(ks[1], (n,), 0, v)
    E = jax.random.normal(ks[2], (n, d)) * 0.7
    g = jax.random.normal(ks[3], (n,))
    return E, C, x, g


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16), n=st.sampled_from([17, 32, 48]),
       v=st.sampled_from([256, 300, 384]), peaked=st.booleans())
def test_fused_backward_bitexact_vs_two_pass(seed, n, v, peaked):
    """Property (a): fused == two-pass gradients BIT-exactly with
    filtering off, at arbitrary (ragged) shapes."""
    E, C, x, g = _cce_problem(seed, n, 32, v, peaked)
    base = dict(block_n=16, block_v=128,
                filter_mode_e="full", filter_mode_c="full")

    def grads(bwd):
        cfg = CCEConfig(bwd=bwd, **base)
        return jax.grad(lambda e, c: jnp.sum(
            linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)

    (dE0, dC0), (dE1, dC1) = grads("two_pass"), grads("fused")
    np.testing.assert_array_equal(np.asarray(dE0), np.asarray(dE1))
    np.testing.assert_array_equal(np.asarray(dC0), np.asarray(dC1))


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**16), n=st.sampled_from([24, 40, 64]),
       v=st.sampled_from([384, 512, 640]), peaked=st.booleans())
def test_fwd_bitmap_superset_property(seed, n, v, peaked):
    """Property (b): the fwd bitmap never marks a block dead that the
    recompute statistic would keep, and label blocks are always live."""
    bn, bv = 16, 128
    E, C, x, _ = _cce_problem(seed, n, 32, v, peaked)
    *_, bm = cce_fwd.cce_forward_pallas(
        E, C, x, block_n=bn, block_v=bv, emit_bitmap=True,
        filter_eps=DEFAULT_FILTER_EPS, interpret=True)
    bm = np.asarray(bm) != 0
    rec = ref.ref_block_live(E, C, x, bn, bv, DEFAULT_FILTER_EPS)
    assert not np.any(rec & ~bm)
    for i, lab in enumerate(np.asarray(x)):
        assert bm[i // bn, lab // bv]
