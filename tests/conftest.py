"""Shared pytest configuration: registers the static-checker fixtures
(`assert_memory_class`, `extract_pallas_calls`, ...) from the
repro.analysis.checks pytest plugin."""

pytest_plugins = ("repro.analysis.checks.pytest_plugin",)
