"""Per-kernel shape/dtype sweeps: Pallas kernels vs. the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (CCEConfig, IGNORE_INDEX, indexed_matmul_pallas,
                           linear_cross_entropy_pallas, lse_and_pick_pallas)
from repro.kernels import ref

SHAPES = [
    # (N, D, V, block_n, block_v)
    (64, 32, 256, 32, 128),
    (96, 64, 384, 32, 128),
    (70, 48, 300, 32, 128),     # ragged N and V edges
    (33, 40, 200, 16, 128),     # ragged everything
    (128, 128, 512, 64, 256),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(n, d, v, dtype, seed=0, ignore_frac=0.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    E = (jax.random.normal(ks[0], (n, d)) * 0.7).astype(dtype)
    C = (jax.random.normal(ks[1], (v, d)) * 0.5).astype(dtype)
    x = jax.random.randint(ks[2], (n,), 0, v)
    if ignore_frac:
        x = jnp.where(jax.random.uniform(ks[3], (n,)) < ignore_frac,
                      IGNORE_INDEX, x)
    g = jax.random.normal(jax.random.PRNGKey(seed + 9), (n,))
    return E, C, x, g


def _tol(dtype):
    return 3e-5 if dtype == jnp.float32 else 5e-2


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_forward_matches_oracle(shape, dtype):
    n, d, v, bn, bv = shape
    E, C, x, _ = _mk(n, d, v, dtype)
    cfg = CCEConfig(block_n=bn, block_v=bv)
    nll = linear_cross_entropy_pallas(E, C, x, cfg)
    nll_ref = ref.ref_linear_cross_entropy(E, C, x)
    assert jnp.max(jnp.abs(nll - nll_ref)) < _tol(dtype)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_backward_matches_autodiff_oracle(shape, dtype):
    n, d, v, bn, bv = shape
    E, C, x, g = _mk(n, d, v, dtype, seed=1)
    cfg = CCEConfig(block_n=bn, block_v=bv)

    def loss(e, c):
        return jnp.sum(linear_cross_entropy_pallas(e, c, x, cfg) * g)

    dE, dC = jax.grad(loss, argnums=(0, 1))(E, C)
    dEr, dCr = ref.ref_grads(E, C, x, g=g)
    tol = _tol(dtype) * 5
    assert jnp.max(jnp.abs(dE.astype(jnp.float32) - dEr)) < tol
    assert jnp.max(jnp.abs(dC.astype(jnp.float32) - dCr)) < tol


@pytest.mark.parametrize("softcap", [None, 30.0, 5.0])
def test_softcap(softcap):
    E, C, x, g = _mk(64, 32, 256, jnp.float32, seed=2)
    cfg = CCEConfig(block_n=32, block_v=128, softcap=softcap)
    nll = linear_cross_entropy_pallas(E, C, x, cfg)
    assert jnp.max(jnp.abs(nll - ref.ref_linear_cross_entropy(
        E, C, x, softcap))) < 3e-5
    dE, dC = jax.grad(lambda e, c: jnp.sum(
        linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)
    dEr, dCr = ref.ref_grads(E, C, x, softcap, g=g)
    assert jnp.max(jnp.abs(dE - dEr)) < 2e-4
    assert jnp.max(jnp.abs(dC - dCr)) < 2e-4


def test_ignore_index_zero_loss_and_grad():
    E, C, x, g = _mk(64, 32, 256, jnp.float32, seed=3, ignore_frac=0.4)
    cfg = CCEConfig(block_n=32, block_v=128)
    nll = linear_cross_entropy_pallas(E, C, x, cfg)
    assert jnp.all(jnp.where(x == IGNORE_INDEX, nll == 0.0, True))
    dE = jax.grad(lambda e: jnp.sum(
        linear_cross_entropy_pallas(e, C, x, cfg)))(E)
    # rows of ignored tokens get exactly zero gradient
    ignored_rows = dE[x == IGNORE_INDEX]
    assert jnp.all(ignored_rows == 0.0)


def test_vocab_sorting_is_exact():
    E, C, x, g = _mk(96, 32, 512, jnp.float32, seed=4)
    base = CCEConfig(block_n=32, block_v=128, sort_vocab=False)
    srt = CCEConfig(block_n=32, block_v=128, sort_vocab=True)

    def grads(cfg):
        return jax.grad(lambda e, c: jnp.sum(
            linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)

    dE0, dC0 = grads(base)
    dE1, dC1 = grads(srt)
    # sorting only reorders block iteration; f32 accumulation order inside a
    # block is fixed, so results agree to float tolerance
    assert jnp.max(jnp.abs(dE0 - dE1)) < 1e-5
    assert jnp.max(jnp.abs(dC0 - dC1)) < 1e-5


@pytest.mark.parametrize("accum", ["f32", "bf16", "bf16_kahan"])
def test_accumulation_modes_run(accum):
    E, C, x, g = _mk(64, 32, 256, jnp.bfloat16, seed=5)
    cfg = CCEConfig(block_n=32, block_v=128, accum=accum)
    dE, dC = jax.grad(lambda e, c: jnp.sum(
        linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)
    dEr, dCr = ref.ref_grads(E, C, x, g=g)
    tol = 0.05 if accum != "f32" else 0.01
    assert jnp.max(jnp.abs(dE.astype(jnp.float32) - dEr)) < tol


def test_kahan_at_least_as_accurate_as_bf16():
    E, C, x, g = _mk(256, 64, 512, jnp.bfloat16, seed=6)
    dEr, dCr = ref.ref_grads(E, C, x, g=g)

    def err(accum):
        cfg = CCEConfig(block_n=32, block_v=128, accum=accum,
                        filter_mode_e="full", filter_mode_c="full")
        dE, dC = jax.grad(lambda e, c: jnp.sum(
            linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)
        return float(jnp.mean(jnp.abs(dC.astype(jnp.float32) - dCr)))

    assert err("bf16_kahan") <= err("bf16") * 1.05


def test_filter_modes():
    """FullC/FullE (no filtering) equal filtered results at fp tolerance —
    the paper's claim that eps=2^-12 filtering is lossless."""
    E, C, x, g = _mk(96, 32, 512, jnp.float32, seed=7)

    def grads(fe, fc):
        cfg = CCEConfig(block_n=32, block_v=128, filter_mode_e=fe,
                        filter_mode_c=fc)
        return jax.grad(lambda e, c: jnp.sum(
            linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)

    dEf, dCf = grads("filtered", "filtered")
    dEn, dCn = grads("full", "full")
    assert jnp.max(jnp.abs(dEf - dEn)) < 2e-4
    assert jnp.max(jnp.abs(dCf - dCn)) < 2e-4


def test_indexed_matmul():
    E, C, x, _ = _mk(33, 64, 100, jnp.float32, seed=8)
    o = indexed_matmul_pallas(E, C, x, interpret=True)
    assert jnp.max(jnp.abs(o - ref.ref_indexed_matmul(E, C, x))) < 1e-5


def test_lse_pick_primitive_general_cotangents():
    """The (lse, pick) primitive must be correct for arbitrary downstream
    functions, not just the NLL (paper §2: separate fwd/bwd enables
    user-defined loss transforms — unlike the Liger design)."""
    E, C, x, _ = _mk(48, 32, 256, jnp.float32, seed=9)
    cfg = CCEConfig(block_n=16, block_v=128)

    def fancy(e, c):
        lse, pick = lse_and_pick_pallas(e, c, x, cfg)
        # z-loss style: nll + 1e-2 * lse^2 (a transform Liger cannot do)
        return jnp.sum((lse - pick) + 1e-2 * lse ** 2)

    def fancy_ref(e, c):
        z = ref.ref_logits(e, c)
        lse = jax.scipy.special.logsumexp(z, axis=-1)
        pick = jnp.take_along_axis(z, x[:, None], 1)[:, 0]
        return jnp.sum((lse - pick) + 1e-2 * lse ** 2)

    dE, dC = jax.grad(fancy, (0, 1))(E, C)
    dEr, dCr = jax.grad(fancy_ref, (0, 1))(E, C)
    assert jnp.max(jnp.abs(dE - dEr)) < 2e-4
    assert jnp.max(jnp.abs(dC - dCr)) < 2e-4
