"""Per-kernel shape/dtype sweeps: Pallas kernels vs. the pure-jnp oracle."""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (CCEConfig, IGNORE_INDEX, indexed_matmul_pallas,
                           linear_cross_entropy_pallas, lse_and_pick_pallas,
                           lse_pick_sum_pallas, vmem_working_set)
from repro.kernels import cce_fwd, ref
from repro.kernels.cce_bwd import DEFAULT_FILTER_EPS

SHAPES = [
    # (N, D, V, block_n, block_v)
    (64, 32, 256, 32, 128),
    (96, 64, 384, 32, 128),
    (70, 48, 300, 32, 128),     # ragged N and V edges
    (33, 40, 200, 16, 128),     # ragged everything
    (128, 128, 512, 64, 256),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(n, d, v, dtype, seed=0, ignore_frac=0.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    E = (jax.random.normal(ks[0], (n, d)) * 0.7).astype(dtype)
    C = (jax.random.normal(ks[1], (v, d)) * 0.5).astype(dtype)
    x = jax.random.randint(ks[2], (n,), 0, v)
    if ignore_frac:
        x = jnp.where(jax.random.uniform(ks[3], (n,)) < ignore_frac,
                      IGNORE_INDEX, x)
    g = jax.random.normal(jax.random.PRNGKey(seed + 9), (n,))
    return E, C, x, g


def _tol(dtype):
    return 3e-5 if dtype == jnp.float32 else 5e-2


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_forward_matches_oracle(shape, dtype):
    n, d, v, bn, bv = shape
    E, C, x, _ = _mk(n, d, v, dtype)
    cfg = CCEConfig(block_n=bn, block_v=bv)
    nll = linear_cross_entropy_pallas(E, C, x, cfg)
    nll_ref = ref.ref_linear_cross_entropy(E, C, x)
    assert jnp.max(jnp.abs(nll - nll_ref)) < _tol(dtype)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_backward_matches_autodiff_oracle(shape, dtype):
    n, d, v, bn, bv = shape
    E, C, x, g = _mk(n, d, v, dtype, seed=1)
    cfg = CCEConfig(block_n=bn, block_v=bv)

    def loss(e, c):
        return jnp.sum(linear_cross_entropy_pallas(e, c, x, cfg) * g)

    dE, dC = jax.grad(loss, argnums=(0, 1))(E, C)
    dEr, dCr = ref.ref_grads(E, C, x, g=g)
    tol = _tol(dtype) * 5
    assert jnp.max(jnp.abs(dE.astype(jnp.float32) - dEr)) < tol
    assert jnp.max(jnp.abs(dC.astype(jnp.float32) - dCr)) < tol


@pytest.mark.parametrize("softcap", [None, 30.0, 5.0])
def test_softcap(softcap):
    E, C, x, g = _mk(64, 32, 256, jnp.float32, seed=2)
    cfg = CCEConfig(block_n=32, block_v=128, softcap=softcap)
    nll = linear_cross_entropy_pallas(E, C, x, cfg)
    assert jnp.max(jnp.abs(nll - ref.ref_linear_cross_entropy(
        E, C, x, softcap))) < 3e-5
    dE, dC = jax.grad(lambda e, c: jnp.sum(
        linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)
    dEr, dCr = ref.ref_grads(E, C, x, softcap, g=g)
    assert jnp.max(jnp.abs(dE - dEr)) < 2e-4
    assert jnp.max(jnp.abs(dC - dCr)) < 2e-4


def test_ignore_index_zero_loss_and_grad():
    E, C, x, g = _mk(64, 32, 256, jnp.float32, seed=3, ignore_frac=0.4)
    cfg = CCEConfig(block_n=32, block_v=128)
    nll = linear_cross_entropy_pallas(E, C, x, cfg)
    assert jnp.all(jnp.where(x == IGNORE_INDEX, nll == 0.0, True))
    dE = jax.grad(lambda e: jnp.sum(
        linear_cross_entropy_pallas(e, C, x, cfg)))(E)
    # rows of ignored tokens get exactly zero gradient
    ignored_rows = dE[x == IGNORE_INDEX]
    assert jnp.all(ignored_rows == 0.0)


def test_vocab_sorting_is_exact():
    E, C, x, g = _mk(96, 32, 512, jnp.float32, seed=4)
    base = CCEConfig(block_n=32, block_v=128, sort_vocab=False)
    srt = CCEConfig(block_n=32, block_v=128, sort_vocab=True)

    def grads(cfg):
        return jax.grad(lambda e, c: jnp.sum(
            linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)

    dE0, dC0 = grads(base)
    dE1, dC1 = grads(srt)
    # sorting only reorders block iteration; f32 accumulation order inside a
    # block is fixed, so results agree to float tolerance
    assert jnp.max(jnp.abs(dE0 - dE1)) < 1e-5
    assert jnp.max(jnp.abs(dC0 - dC1)) < 1e-5


@pytest.mark.parametrize("accum", ["f32", "bf16", "bf16_kahan"])
def test_accumulation_modes_run(accum):
    E, C, x, g = _mk(64, 32, 256, jnp.bfloat16, seed=5)
    cfg = CCEConfig(block_n=32, block_v=128, accum=accum)
    dE, dC = jax.grad(lambda e, c: jnp.sum(
        linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)
    dEr, dCr = ref.ref_grads(E, C, x, g=g)
    tol = 0.05 if accum != "f32" else 0.01
    assert jnp.max(jnp.abs(dE.astype(jnp.float32) - dEr)) < tol


def test_kahan_at_least_as_accurate_as_bf16():
    E, C, x, g = _mk(256, 64, 512, jnp.bfloat16, seed=6)
    dEr, dCr = ref.ref_grads(E, C, x, g=g)

    def err(accum):
        cfg = CCEConfig(block_n=32, block_v=128, accum=accum,
                        filter_mode_e="full", filter_mode_c="full")
        dE, dC = jax.grad(lambda e, c: jnp.sum(
            linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)
        return float(jnp.mean(jnp.abs(dC.astype(jnp.float32) - dCr)))

    assert err("bf16_kahan") <= err("bf16") * 1.05


def test_filter_modes():
    """FullC/FullE (no filtering) equal filtered results at fp tolerance —
    the paper's claim that eps=2^-12 filtering is lossless."""
    E, C, x, g = _mk(96, 32, 512, jnp.float32, seed=7)

    def grads(fe, fc):
        cfg = CCEConfig(block_n=32, block_v=128, filter_mode_e=fe,
                        filter_mode_c=fc)
        return jax.grad(lambda e, c: jnp.sum(
            linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)

    dEf, dCf = grads("filtered", "filtered")
    dEn, dCn = grads("full", "full")
    assert jnp.max(jnp.abs(dEf - dEn)) < 2e-4
    assert jnp.max(jnp.abs(dCf - dCn)) < 2e-4


# ---------------------------------------------------------------------------
# Fused single-pass backward + forward-emitted block-sparsity maps
# (DESIGN.md §7).
# ---------------------------------------------------------------------------

def _peaked(n, d, v, hot=64, seed=11, ignore_frac=0.0):
    """ref.peaked_problem (shared with the benchmarks), plus optional
    IGNORE_INDEX masking."""
    E, C, x, g = ref.peaked_problem(n, d, v, hot=hot, seed=seed)
    if ignore_frac:
        mask = jax.random.uniform(jax.random.PRNGKey(seed + 2), (n,))
        x = jnp.where(mask < ignore_frac, IGNORE_INDEX, x)
    return E, C, x, g


def _grads(E, C, x, g, cfg):
    return jax.grad(lambda e, c: jnp.sum(
        linear_cross_entropy_pallas(e, c, x, cfg) * g), (0, 1))(E, C)


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_fused_bitexact_vs_two_pass_filter_off(shape, dtype, softcap):
    """Acceptance bar: the fused single-pass backward is BIT-IDENTICAL to
    the two-pass kernels with filtering off — same addends, same order,
    same f32 accumulation (the dC HBM-revisit accumulation is f32 and cast
    once, exactly like the two-pass VMEM scratch)."""
    n, d, v, bn, bv = shape
    E, C, x, g = _mk(n, d, v, dtype, seed=21)
    base = dict(block_n=bn, block_v=bv, softcap=softcap,
                filter_mode_e="full", filter_mode_c="full")
    dE0, dC0 = _grads(E, C, x, g, CCEConfig(bwd="two_pass", **base))
    dE1, dC1 = _grads(E, C, x, g, CCEConfig(bwd="fused", **base))
    np.testing.assert_array_equal(np.asarray(dE0), np.asarray(dE1))
    np.testing.assert_array_equal(np.asarray(dC0), np.asarray(dC1))


def test_fused_bitexact_vs_two_pass_filter_on():
    """With the shared recompute statistic the gating decisions are
    identical too, so bit-exactness extends to filtering ON — including a
    genuinely sparse (peaked) problem where blocks really are skipped."""
    E, C, x, g = _peaked(96, 32, 1024)
    base = dict(block_n=32, block_v=128, filter_stats="recompute")
    dE0, dC0 = _grads(E, C, x, g, CCEConfig(bwd="two_pass", **base))
    dE1, dC1 = _grads(E, C, x, g, CCEConfig(bwd="fused", **base))
    np.testing.assert_array_equal(np.asarray(dE0), np.asarray(dE1))
    np.testing.assert_array_equal(np.asarray(dC0), np.asarray(dC1))


def test_fused_with_sum_matches_dense_autodiff():
    """The fused path must serve the three-output primitive (dense g_sum
    cotangent forces filtering off) bit-identically to two_pass and to
    tolerance against dense autodiff."""
    E, C, x, g = _mk(48, 32, 300, jnp.float32, seed=22)

    def loss(bwd):
        cfg = CCEConfig(block_n=16, block_v=128, bwd=bwd)

        def f(e, c):
            lse, pick, z = lse_pick_sum_pallas(e, c, x, cfg)
            return jnp.sum((lse - pick) * g + 1e-3 * z)
        return jax.grad(f, (0, 1))(E, C)

    dE0, dC0 = loss("two_pass")
    dE1, dC1 = loss("fused")
    np.testing.assert_array_equal(np.asarray(dE0), np.asarray(dE1))
    np.testing.assert_array_equal(np.asarray(dC0), np.asarray(dC1))

    def f_ref(e, c):
        z = ref.ref_logits(e, c)
        lse = jax.scipy.special.logsumexp(z, axis=-1)
        pick = jnp.take_along_axis(z, x[:, None], 1)[:, 0]
        return jnp.sum((lse - pick) * g + 1e-3 * jnp.sum(z, -1))

    dEr, dCr = jax.grad(f_ref, (0, 1))(E, C)
    assert jnp.max(jnp.abs(dE1 - dEr)) < 2e-4
    assert jnp.max(jnp.abs(dC1 - dCr)) < 2e-4


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("peaked", [False, True])
def test_fwd_bitmap_never_drops_a_live_block(seed, peaked):
    """The forward-emitted bitmap is a conservative superset of the
    recompute statistic: any block Alg. 4 keeps is marked live, and every
    label-containing block is live unconditionally."""
    n, d, v, bn, bv = 70, 32, 640, 32, 128
    if peaked:
        E, C, x, _ = _peaked(n, d, v, seed=seed + 30)
        x = jnp.where(x == IGNORE_INDEX, 0, x)
    else:
        E, C, x, _ = _mk(n, d, v, jnp.float32, seed=seed)
    *_, bm = cce_fwd.cce_forward_pallas(
        E, C, x, block_n=bn, block_v=bv, emit_bitmap=True,
        filter_eps=DEFAULT_FILTER_EPS, interpret=True)
    bm = np.asarray(bm) != 0
    rec = ref.ref_block_live(E, C, x, bn, bv, DEFAULT_FILTER_EPS)
    assert not np.any(rec & ~bm), "bitmap dropped a block Alg. 4 keeps"
    for i, lab in enumerate(np.asarray(x)):
        assert bm[i // bn, lab // bv], "label block must always be live"


@pytest.mark.parametrize("bwd", ["two_pass", "fused"])
def test_fwd_bitmap_grads_match_full_on_sparse_problem(bwd):
    """On a peaked problem where filtering genuinely skips blocks, the
    bitmap-gated backward stays within the paper's lossless-filtering
    tolerance of the unfiltered gradients (and is at least as accurate as
    recompute-stat filtering, being a superset)."""
    E, C, x, g = _peaked(128, 64, 1024, ignore_frac=0.2)
    base = dict(block_n=32, block_v=128)
    dEf, dCf = _grads(E, C, x, g, CCEConfig(
        filter_mode_e="full", filter_mode_c="full", **base))
    dEb, dCb = _grads(E, C, x, g, CCEConfig(
        bwd=bwd, filter_stats="fwd_bitmap", **base))
    # dropped entries are < eps = 2^-12 each; the residual is the sum of a
    # dead block's sub-eps tail — well under bf16 training noise (paper
    # §4.3's losslessness claim), but not zero.
    assert jnp.max(jnp.abs(dEb - dEf)) < 1e-2
    assert jnp.max(jnp.abs(dCb - dCf)) < 1e-2
    # the bitmap really does gate: the peaked problem has dead blocks
    sx = jnp.where(x == IGNORE_INDEX, 0, x)
    *_, bm = cce_fwd.cce_forward_pallas(
        E, C, sx, block_n=32, block_v=128, emit_bitmap=True,
        filter_eps=DEFAULT_FILTER_EPS, interpret=True)
    assert float((np.asarray(bm) != 0).mean()) < 1.0


@pytest.mark.parametrize("bwd", ["two_pass", "fused"])
def test_sort_vocab_composes_with_fwd_bitmap(bwd):
    """sort_vocab permutes C rows before the backward; the bitmap's v axis
    must be re-blocked under the permutation (conservative row-expansion),
    or live rows would land in blocks marked dead."""
    E, C, x, g = _peaked(96, 32, 1024, seed=41)
    base = dict(block_n=32, block_v=128)
    dEf, dCf = _grads(E, C, x, g, CCEConfig(
        filter_mode_e="full", filter_mode_c="full", **base))
    dEs, dCs = _grads(E, C, x, g, CCEConfig(
        bwd=bwd, filter_stats="fwd_bitmap", sort_vocab=True, **base))
    assert jnp.max(jnp.abs(dEs - dEf)) < 2e-3
    assert jnp.max(jnp.abs(dCs - dCf)) < 2e-3


def test_fused_falls_back_for_kahan_accum():
    """bwd="fused" requires f32 accumulation; other modes silently use the
    two-pass kernels (documented fallback), so results still match the
    explicit two_pass config."""
    E, C, x, g = _mk(64, 32, 256, jnp.bfloat16, seed=23)
    base = dict(block_n=32, block_v=128, accum="bf16_kahan")
    dE0, dC0 = _grads(E, C, x, g, CCEConfig(bwd="two_pass", **base))
    dE1, dC1 = _grads(E, C, x, g, CCEConfig(bwd="fused", **base))
    np.testing.assert_array_equal(np.asarray(dE0), np.asarray(dE1))
    np.testing.assert_array_equal(np.asarray(dC0), np.asarray(dC1))


def test_cceconfig_rejects_invalid_values():
    with pytest.raises(ValueError):
        CCEConfig(bwd="single_pass")
    with pytest.raises(ValueError):
        CCEConfig(filter_stats="oracle")
    with pytest.raises(ValueError):
        CCEConfig(filter_mode_e="off")
    with pytest.raises(ValueError):
        CCEConfig(accum="f64")


def test_choose_blocks_fit_paper_geometries():
    """The VMEM-fit estimate must cover every optional buffer (with_sum
    column, Kahan compensation, bitmap staging scratch) at the paper
    geometries of the assigned configs — a knob can never silently
    overflow the budget at a block shape chosen without it."""
    import repro.configs as configs
    from repro.kernels.ops import _VMEM_BUDGET, choose_blocks

    n_tokens = 8192
    for arch in configs.ASSIGNED:
        cfg = configs.get_config(arch)
        v, d = cfg.padded_vocab_size, cfg.d_model
        for with_sum in (False, True):
            for emit_bitmap in (False, True):
                for kahan in (False, True):
                    for accum_rows in (1, 2):
                        bn, bv = choose_blocks(
                            n_tokens, v, d, 2, accum_rows,
                            with_sum=with_sum, emit_bitmap=emit_bitmap,
                            kahan=kahan)
                        ws = vmem_working_set(
                            bn, bv, d, 2, accum_rows, with_sum=with_sum,
                            emit_bitmap=emit_bitmap, vocab=v, kahan=kahan)
                        assert ws <= _VMEM_BUDGET, (
                            arch, with_sum, emit_bitmap, kahan, accum_rows,
                            bn, bv, ws)
                        assert bn % 8 == 0 and bv % 128 == 0, (arch, bn, bv)


def test_indexed_matmul():
    E, C, x, _ = _mk(33, 64, 100, jnp.float32, seed=8)
    o = indexed_matmul_pallas(E, C, x, interpret=True)
    assert jnp.max(jnp.abs(o - ref.ref_indexed_matmul(E, C, x))) < 1e-5


def test_lse_pick_primitive_general_cotangents():
    """The (lse, pick) primitive must be correct for arbitrary downstream
    functions, not just the NLL (paper §2: separate fwd/bwd enables
    user-defined loss transforms — unlike the Liger design)."""
    E, C, x, _ = _mk(48, 32, 256, jnp.float32, seed=9)
    cfg = CCEConfig(block_n=16, block_v=128)

    def fancy(e, c):
        lse, pick = lse_and_pick_pallas(e, c, x, cfg)
        # z-loss style: nll + 1e-2 * lse^2 (a transform Liger cannot do)
        return jnp.sum((lse - pick) + 1e-2 * lse ** 2)

    def fancy_ref(e, c):
        z = ref.ref_logits(e, c)
        lse = jax.scipy.special.logsumexp(z, axis=-1)
        pick = jnp.take_along_axis(z, x[:, None], 1)[:, 0]
        return jnp.sum((lse - pick) + 1e-2 * lse ** 2)

    dE, dC = jax.grad(fancy, (0, 1))(E, C)
    dEr, dCr = jax.grad(fancy_ref, (0, 1))(E, C)
    assert jnp.max(jnp.abs(dE - dEr)) < 2e-4
    assert jnp.max(jnp.abs(dC - dCr)) < 2e-4
