"""Direct coverage for the ``repro.analysis.hlo`` parsers.

Feeds *real* optimized-HLO dumps — one per registered backend, lowered
through the public ``cross_entropy`` dispatch — through
``parse_computations`` / ``analyze`` / ``array_shape_census``, plus
deterministic corruption fuzzing and (when hypothesis is installed)
property tests: the parsers must never raise on arbitrary text and their
outputs must stay structurally sane.
"""

import random

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo as hlo_an
from repro.backends import base as backends
from repro.core import cross_entropy

# V must exceed cce_jax's 2048-wide vocab tile or the twin's largest
# buffer *is* N·V and the census class test cannot discriminate
N, V, D = 512, 8192, 64


@pytest.fixture(scope="module")
def backend_dumps():
    """{backend_name: optimized HLO text} for every registered backend."""
    dumps = {}
    for name in backends.list_backends():
        def f(E, C, x, impl=name):
            return cross_entropy(E, C, x, impl=impl, reduction="mean")

        g = jax.value_and_grad(f, argnums=(0, 1))
        dumps[name] = jax.jit(g).lower(
            jax.ShapeDtypeStruct((N, D), jnp.float32),
            jax.ShapeDtypeStruct((V, D), jnp.float32),
            jax.ShapeDtypeStruct((N,), jnp.int32)).compile().as_text()
    return dumps


def test_parse_computations_structure(backend_dumps):
    """Every dump parses into named computations whose symbol tables cover
    their own instructions, with exactly one ROOT per computation."""
    for name, text in backend_dumps.items():
        comps, types = hlo_an.parse_computations(text)
        assert comps, f"{name}: no computations parsed"
        assert set(comps) == set(types)
        for cname, instrs in comps.items():
            assert instrs, f"{name}/{cname}: empty computation"
            roots = [i for i in instrs if i.is_root]
            assert len(roots) == 1, f"{name}/{cname}: {len(roots)} ROOTs"
            for ins in instrs:
                assert types[cname][ins.name] == ins.out_type
                assert ins.opcode and not ins.opcode.startswith("%")


def test_analyze_outputs_sane(backend_dumps):
    """flops/traffic are positive finite; no collectives on one device;
    analyze is deterministic; an explicit entry= reproduces the default."""
    for name, text in backend_dumps.items():
        out = hlo_an.analyze(text)
        assert out["flops"] > 0, f"{name}: no dot flops found"
        assert out["traffic_bytes"] > 0
        assert out["collective_bytes"] == 0
        assert out["collective_wire_bytes"] == 0
        assert out["collectives"] == {}
        again = hlo_an.analyze(text)
        assert again["flops"] == out["flops"]
        assert again["traffic_bytes"] == out["traffic_bytes"]


def test_analyze_flops_lower_bound(backend_dumps):
    """Every backend must at least run the forward logit matmul
    (2·N·V·D dot flops); pure-XLA backends additionally run the dE/dC
    matmuls, so dense/cce_jax/chunked/liger see >= 3·2·N·V·D. (The
    Pallas backend's backward lowers through a custom call whose inner
    dots analyze cannot attribute — only the floor is universal.)"""
    fwd = 2 * N * V * D
    for name, text in backend_dumps.items():
        out = hlo_an.analyze(text)
        assert out["flops"] >= 0.9 * fwd, \
            f"{name}: {out['flops']:.3g} < {0.9 * fwd:.3g}"
    for name in ("dense", "cce_jax", "chunked", "liger"):
        out = hlo_an.analyze(backend_dumps[name])
        assert out["flops"] >= 0.99 * 3 * fwd, \
            f"{name}: {out['flops']:.3g} < fwd+dE+dC flops"


def test_census_ordering_and_classes(backend_dumps):
    """Census is sorted descending, respects top=k, and separates the
    dense backend (has an N·V buffer) from the CCE-class ones."""
    for name, text in backend_dumps.items():
        census = hlo_an.array_shape_census(text, top=5)
        assert 0 < len(census) <= 5
        elems = [e for e, _ in census]
        assert elems == sorted(elems, reverse=True)
        assert all(e > 0 for e in elems)
        top1 = hlo_an.array_shape_census(text, top=1)
        assert top1[0] == census[0]
    assert hlo_an.array_shape_census(
        backend_dumps["dense"], top=1)[0][0] >= N * V
    for name in ("cce", "cce_jax"):
        assert hlo_an.array_shape_census(
            backend_dumps[name], top=1)[0][0] < N * V


def test_while_trip_count_multiplier():
    """A scan of K matmuls must report ~K times the flops of one matmul
    (the while-loop body is counted trip-count times, not once)."""
    k, m = 8, 64

    def one(a, b):
        return a @ b

    def scanned(a, b):
        def step(carry, _):
            return carry @ b, None
        out, _ = jax.lax.scan(step, a, None, length=k)
        return out

    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    b = jax.ShapeDtypeStruct((m, m), jnp.float32)
    f1 = hlo_an.analyze(jax.jit(one).lower(a, b).compile().as_text())
    fk = hlo_an.analyze(jax.jit(scanned).lower(a, b).compile().as_text())
    assert f1["flops"] >= 2 * m ** 3
    # XLA may unroll small scans; either way the work is ~k matmuls
    assert fk["flops"] >= 0.9 * k * 2 * m ** 3


def test_parsers_survive_corruption(backend_dumps):
    """Deterministic fuzz: dropping, duplicating, or truncating lines of a
    real dump must never raise — partial modules yield partial answers."""
    rng = random.Random(0)
    for name, text in backend_dumps.items():
        lines = text.splitlines()
        for trial in range(10):
            mutated = [ln for ln in lines if rng.random() > 0.2]
            rng.shuffle(mutated[: len(mutated) // 8])
            for chunk in ("\n".join(mutated),
                          text[: len(text) // 2],
                          text[len(text) // 3:]):
                comps, types = hlo_an.parse_computations(chunk)
                assert isinstance(comps, dict) and isinstance(types, dict)
                out = hlo_an.analyze(chunk)
                assert out["flops"] >= 0
                assert out["traffic_bytes"] >= 0
                census = hlo_an.array_shape_census(chunk, top=3)
                assert all(e >= 0 for e, _ in census)


def test_census_empty_and_garbage():
    assert hlo_an.array_shape_census("", top=4) == []
    out = hlo_an.analyze("")
    assert out["flops"] == 0 and out["traffic_bytes"] == 0
    comps, types = hlo_an.parse_computations("not hlo at all\n{}{}\n")
    assert comps == {} and types == {}


def test_property_parsers_total():
    """Hypothesis: parse/analyze/census are total functions of text —
    arbitrary unicode, including HLO-ish fragments, never raises."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    fragments = st.sampled_from([
        "ENTRY %main (p0: f32[8,16]) -> f32[8,16] {\n",
        "  ROOT %dot = f32[8,16] dot(%a, %b), lhs_contracting_dims={1}\n",
        "  %w = f32[4,4] while(%init), body=%b, condition=%c\n",
        "}\n", "f32[1024,2048]", "garbage ( { ) }", "\n",
    ])
    text_strategy = st.lists(
        st.one_of(fragments, st.text(max_size=64)), max_size=30
    ).map("".join)

    @given(text_strategy)
    @settings(max_examples=60, deadline=None)
    def run(text):
        comps, types = hlo_an.parse_computations(text)
        assert isinstance(comps, dict) and isinstance(types, dict)
        out = hlo_an.analyze(text)
        assert out["flops"] >= 0 and out["traffic_bytes"] >= 0
        for e, desc in hlo_an.array_shape_census(text, top=4):
            assert e >= 0 and isinstance(desc, str)

    run()
