"""repro.losses: registry round-trip, gradchecks of every CCE-backed loss
against independently-written dense formulas, reduction parity across
implementations (including IGNORE_INDEX tokens), and the same gradchecks
routed through ``cross_entropy(..., mesh=...)`` — every registry loss must
match values/grads sharded and local."""

import dataclasses
import os
import subprocess
import sys
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import CCEConfig
from repro.kernels.ref import IGNORE_INDEX
from repro.losses import LossConfig, get_loss, list_losses
from repro.losses.base import VocabLoss

IMPLS = ("cce", "cce_jax", "dense")

# every registry entry with the hyper-parameters the tests exercise
CASES = {
    "nll": {},
    "z_loss": {"z_weight": 1e-3},
    "focal": {"gamma": 2.0},
    "weighted": {},
    "label_smoothing": {"eps": 0.1},
    "seq_logprob": {},
}


def _problem(n=40, d=32, v=300, seed=0, ignore_frac=0.25):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    E = jax.random.normal(ks[0], (n, d)) * 0.7
    C = jax.random.normal(ks[1], (v, d)) * 0.5
    x = jax.random.randint(ks[2], (n,), 0, v)
    if ignore_frac:
        x = jnp.where(jax.random.uniform(ks[3], (n,)) < ignore_frac,
                      IGNORE_INDEX, x)
    w = jnp.abs(jax.random.normal(ks[4], (n,))) + 0.1
    return E, C, x, w


# ---------------------------------------------------------------------------
# Independent dense references (full softmax; deliberately NOT via the
# lse_and_pick code path, so they cross-check the primitive itself).
# ---------------------------------------------------------------------------

def _logits(E, C):
    return jnp.dot(E.astype(jnp.float32), C.astype(jnp.float32).T)


def _dense_ref(name, kwargs, E, C, x, w=None):
    z = _logits(E, C)
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    safe = jnp.where(x == IGNORE_INDEX, 0, x)
    pick = jnp.take_along_axis(z, safe[:, None], -1)[:, 0]
    nll = lse - pick
    if name == "nll" or name == "weighted":
        out = nll
    elif name == "z_loss":
        out = nll + kwargs["z_weight"] * lse ** 2
    elif name == "focal":
        p = jnp.exp(pick - lse)
        out = (1.0 - p) ** kwargs["gamma"] * nll
    elif name == "label_smoothing":
        eps = kwargs["eps"]
        # CE against the smoothed target distribution, written as
        # sum_j q_j * (lse - z_j) with q = (1-eps)*onehot + eps/V.
        q = ((1.0 - eps) * jax.nn.one_hot(safe, C.shape[0])
             + eps / C.shape[0])
        out = jnp.sum(q * (lse[:, None] - z), axis=-1)
    elif name == "seq_logprob":
        out = pick - lse
    else:
        raise AssertionError(name)
    if w is not None:
        out = out * w
    return jnp.where(x == IGNORE_INDEX, 0.0, out)


# ---------------------------------------------------------------------------
# Registry round-trip.
# ---------------------------------------------------------------------------

def test_registry_roundtrip_every_name():
    assert len(list_losses()) >= 5
    for name in list_losses():
        kwargs = CASES.get(name, {})
        obj = get_loss(name, **kwargs)
        assert isinstance(obj, VocabLoss)
        assert obj.name == name
        # LossConfig carries the same information, hashably
        cfg = LossConfig.create(name, **kwargs)
        rebuilt = cfg.build()
        assert rebuilt == obj
        hash(cfg)  # must be usable as a static jit arg


def test_registry_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown loss"):
        get_loss("not_a_loss")


def test_registry_covers_issue_minimum():
    for required in ("nll", "z_loss", "focal", "weighted",
                     "label_smoothing", "seq_logprob"):
        assert required in list_losses()


# ---------------------------------------------------------------------------
# Forward + gradient checks vs the independent dense formulas.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [n for n in CASES if n != "seq_logprob"])
@pytest.mark.parametrize("impl", IMPLS)
def test_loss_matches_dense_reference(name, impl):
    E, C, x, w = _problem(seed=zlib.crc32(name.encode()) % 1000)
    weights = w if name == "weighted" else None
    loss = get_loss(name, **CASES[name])
    cfg = CCEConfig(block_n=16, block_v=128)

    out = loss(E, C, x, impl=impl, cfg=cfg, weights=weights)
    ref = _dense_ref(name, CASES[name], E, C, x, weights)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)

    def f(e, c):
        return loss(e, c, x, impl=impl, cfg=cfg, reduction="mean",
                    weights=weights)

    def f_ref(e, c):
        per = _dense_ref(name, CASES[name], e, c, x, weights)
        denom = (jnp.sum(jnp.where(x != IGNORE_INDEX, weights, 0.0))
                 if weights is not None
                 else jnp.sum(x != IGNORE_INDEX))
        return jnp.sum(per) / jnp.maximum(denom, 1e-8)

    dE, dC = jax.grad(f, argnums=(0, 1))(E, C)
    dEr, dCr = jax.grad(f_ref, argnums=(0, 1))(E, C)
    np.testing.assert_allclose(np.asarray(dE), np.asarray(dEr),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dC), np.asarray(dCr),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_seq_logprob_scoring(impl):
    E, C, x, _ = _problem(n=48, ignore_frac=0.2, seed=11)
    B, S = 4, 12
    Eb, xb = E.reshape(B, S, -1), x.reshape(B, S)
    per_tok = _dense_ref("seq_logprob", {}, E, C, x).reshape(B, S)
    valid = (xb != IGNORE_INDEX)

    score = get_loss("seq_logprob")(Eb, C, xb, impl=impl)
    np.testing.assert_allclose(np.asarray(score),
                               np.asarray(jnp.sum(per_tok, axis=1)),
                               rtol=1e-4, atol=1e-5)

    norm = get_loss("seq_logprob", normalize="tokens")(Eb, C, xb, impl=impl)
    ref = jnp.sum(per_tok, 1) / jnp.maximum(jnp.sum(valid, 1), 1)
    np.testing.assert_allclose(np.asarray(norm), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)

    # scoring objectives are gradcheckable too (rescoring-through-training)
    g = jax.grad(lambda e: jnp.sum(
        get_loss("seq_logprob")(e, C, xb, impl=impl)))(Eb)
    g_ref = jax.grad(lambda e: jnp.sum(jnp.where(
        xb != IGNORE_INDEX,
        _dense_ref("seq_logprob", {}, e.reshape(-1, e.shape[-1]), C,
                   x).reshape(B, S), 0.0)))(Eb)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Reduction parity across impls, with ignored tokens in the batch.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [n for n in CASES if n != "seq_logprob"])
@pytest.mark.parametrize("reduction", ["mean", "sum"])
def test_reduction_parity_across_impls(name, reduction):
    E, C, x, w = _problem(ignore_frac=0.4, seed=23)
    assert bool(jnp.any(x == IGNORE_INDEX))
    weights = w if name == "weighted" else None
    loss = get_loss(name, **CASES[name])
    cfg = CCEConfig(block_n=16, block_v=128)
    vals = [float(loss(E, C, x, impl=impl, cfg=cfg, reduction=reduction,
                       weights=weights))
            for impl in IMPLS]
    for v in vals[1:]:
        assert abs(v - vals[0]) <= 1e-4 * max(1.0, abs(vals[0])), \
            (name, reduction, vals)


def test_ignored_tokens_contribute_no_loss_or_grad():
    E, C, x, _ = _problem(ignore_frac=0.5, seed=31)
    loss = get_loss("label_smoothing", eps=0.1)
    cfg = CCEConfig(block_n=16, block_v=128)
    per = loss(E, C, x, impl="cce", cfg=cfg)
    assert bool(jnp.all(jnp.where(x == IGNORE_INDEX, per == 0.0, True)))
    dE = jax.grad(lambda e: float(0) + loss(e, C, x, impl="cce", cfg=cfg,
                                            reduction="sum"))(E)
    assert bool(jnp.all(dE[x == IGNORE_INDEX] == 0.0))


# ---------------------------------------------------------------------------
# Stack wiring: train_loss resolves losses via the registry.
# ---------------------------------------------------------------------------

def test_train_loss_uses_registry():
    from repro.models import transformer as T
    cfg = dataclasses.replace(
        __import__("repro.configs", fromlist=["x"]).get_reduced_config(
            "llama3_2_3b"),
        dtype="float32", loss_impl="cce_jax")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"tokens": jax.random.randint(ks[0], (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (2, 16), 0, cfg.vocab_size)}
    base = float(T.train_loss(params, cfg, batch))
    zl = float(T.train_loss(params, cfg, batch, loss="z_loss",
                            loss_kwargs={"z_weight": 1e-3}))
    ls = float(T.train_loss(params, cfg, batch, loss="label_smoothing",
                            loss_kwargs={"eps": 0.1}))
    assert zl > base            # lse^2 penalty is positive
    assert ls != base
    with pytest.raises(ValueError, match="scoring objective"):
        T.train_loss(params, cfg, batch, loss="seq_logprob")


def test_train_loss_weighted_completion_mask():
    """loss='weighted' + a completion mask == mean NLL over completion."""
    from repro.models import transformer as T
    cfg = dataclasses.replace(
        __import__("repro.configs", fromlist=["x"]).get_reduced_config(
            "llama3_2_3b"),
        dtype="float32", loss_impl="cce_jax")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    tokens = jax.random.randint(ks[0], (2, 16), 0, cfg.vocab_size)
    labels = jax.random.randint(ks[1], (2, 16), 0, cfg.vocab_size)
    mask = jnp.concatenate([jnp.zeros((2, 8)), jnp.ones((2, 8))], axis=1)
    got = float(T.train_loss(
        params, cfg, {"tokens": tokens, "labels": labels,
                      "loss_weights": mask}, loss="weighted"))
    # reference: mask via IGNORE_INDEX instead
    masked_labels = jnp.where(mask > 0, labels, IGNORE_INDEX)
    want = float(T.train_loss(
        params, cfg, {"tokens": tokens, "labels": masked_labels}))
    assert abs(got - want) < 1e-5, (got, want)


# ---------------------------------------------------------------------------
# Vocab-parallel execution: the SAME losses through cross_entropy(mesh=...)
# must match the local dense reference in values and gradients. Runs in a
# subprocess with 8 forced host devices (jax locks the device count at
# first init; the main pytest process must keep seeing one device).
# ---------------------------------------------------------------------------

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sharded(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# z_loss: pure-cotangent extra term through the lse psum combine;
# label_smoothing: exercises the sum_logits third output -> one extra psum
# end-to-end (forward AND the dense uniform-target backward).
@pytest.mark.parametrize("name,kwargs", [
    ("z_loss", {"z_weight": 1e-3}),
    ("label_smoothing", {"eps": 0.1}),
])
def test_registry_loss_vocab_parallel_matches_local(name, kwargs):
    out = _run_sharded(f"""
import jax, jax.numpy as jnp
from repro.core import cross_entropy
from repro.kernels.ref import IGNORE_INDEX
from repro.launch.mesh import make_test_mesh
from repro.losses import get_loss

mesh = make_test_mesh((2, 4), ("data", "model"))
ks = jax.random.split(jax.random.PRNGKey(3), 3)
E = jax.random.normal(ks[0], (64, 32)) * 0.7
C = jax.random.normal(ks[1], (512, 32)) * 0.5
x = jax.random.randint(ks[2], (64,), 0, 512)
x = jnp.where(jax.random.uniform(jax.random.PRNGKey(7), (64,)) < 0.25,
              IGNORE_INDEX, x)
assert bool(jnp.any(x == IGNORE_INDEX))

loss = get_loss({name!r}, **{kwargs!r})
per_sh = cross_entropy(E, C, x, loss=loss, impl="cce_jax", mesh=mesh)
per_ref = cross_entropy(E, C, x, loss=loss, impl="dense")
assert float(jnp.max(jnp.abs(per_sh - per_ref))) < 1e-4
assert bool(jnp.all(jnp.where(x == IGNORE_INDEX, per_sh == 0.0, True)))

def f(e, c):
    return cross_entropy(e, c, x, loss=loss, impl="cce_jax",
                         mesh=mesh, reduction="mean")
def f_ref(e, c):
    return cross_entropy(e, c, x, loss=loss, impl="dense",
                         reduction="mean")
assert abs(float(f(E, C)) - float(f_ref(E, C))) < 1e-5
dE, dC = jax.grad(f, argnums=(0, 1))(E, C)
dEr, dCr = jax.grad(f_ref, argnums=(0, 1))(E, C)
assert float(jnp.max(jnp.abs(dE - dEr))) < 1e-4
assert float(jnp.max(jnp.abs(dC - dCr))) < 1e-4
print("OK")
""")
    assert "OK" in out


def test_train_loss_routes_mesh_through_cross_entropy():
    """train_loss(mesh=...) — the production head — matches the local head
    for a registry loss (label smoothing, so the sum_logits psum rides the
    full model fwd+bwd), with C sharded over the model axis."""
    out = _run_sharded("""
import dataclasses
import jax, jax.numpy as jnp
import repro.configs as configs
from repro.models import transformer as T
from repro.launch.mesh import make_test_mesh

cfg = dataclasses.replace(configs.get_reduced_config("llama3_2_3b"),
                          dtype="float32", loss_impl="cce_jax")
mesh = make_test_mesh((2, 4), ("data", "model"))
params = T.init_lm(jax.random.PRNGKey(0), cfg)
ks = jax.random.split(jax.random.PRNGKey(1), 2)
batch = {"tokens": jax.random.randint(ks[0], (2, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (2, 16), 0, cfg.vocab_size)}
kw = dict(loss="label_smoothing", loss_kwargs={"eps": 0.1})
local = float(T.train_loss(params, cfg, batch, **kw))
sharded = float(T.train_loss(params, cfg, batch, mesh=mesh,
                             token_axes=("data",), **kw))
assert abs(local - sharded) < 1e-5, (local, sharded)
print("OK")
""")
    assert "OK" in out
