"""Speculative-decoding tests: greedy golden equivalence across every
mixer family and both drafters, the residual-sampling distribution
contract (TV distance), kvpool rollback invariants, and the preserved
one-host-transfer-per-step property of the spec engine loop."""

import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer as T
from repro.obs import Registry
from repro.serve import Engine, SamplingParams
from repro.serve import sampling as sampling_mod
from repro.serve import scheduler as sched_mod
from repro.serve import speculative as spec_mod


def _cfg(arch="llama3_2_3b", **over):
    return dataclasses.replace(configs.get_reduced_config(arch),
                               dtype="float32", **over)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [9, 8, 7], [11, 12, 13, 14]]

ALL_ARCHS = ["llama3_2_3b", "gemma2_2b", "recurrentgemma_9b", "rwkv6_3b",
             "olmoe_1b_7b"]


# ---------------------------------------------------------------------------
# Golden equivalence: greedy speculation is exact — token-identical to
# the plain engine for every mixer family, including mid-flight
# admission (4 requests through 2 slots), for both drafters.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_spec_greedy_matches_plain_all_mixers(arch):
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    ref = Engine(cfg, params, max_len=48, batch_size=2).generate(
        PROMPTS, 5)
    out = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=3).generate(PROMPTS, 5)
    assert out == ref


@pytest.mark.parametrize("arch", ["llama3_2_3b", "rwkv6_3b"])
def test_spec_draft_model_matches_plain(arch):
    """The draft-transformer drafter changes only which tokens are
    *proposed*; verification keeps the emitted stream exact (rwkv6
    additionally exercises the replay-commit path under a draft)."""
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    draft_cfg = cfg
    draft_params = T.init_lm(jax.random.PRNGKey(1), cfg)
    ref = Engine(cfg, params, max_len=48, batch_size=2).generate(
        PROMPTS, 5)
    out = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=2, draft_cfg=draft_cfg,
                 draft_params=draft_params).generate(PROMPTS, 5)
    assert out == ref


SHARED_PREFIX = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]
PREFIX_PROMPTS = [SHARED_PREFIX + tail
                  for tail in ([7], [8, 9], [10, 11, 12], [13])]


def test_spec_paged_shared_prefix_matches_plain(model):
    """Speculation composes with the paged KV pool and copy-free prefix
    reuse: same tokens, and the prefix registry still hits."""
    cfg, params = model
    ref = Engine(cfg, params, max_len=48, batch_size=2).generate(
        PREFIX_PROMPTS, 5)
    eng = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=3, kv_page_size=4)
    out = eng.generate(PREFIX_PROMPTS, 5)
    assert out == ref
    assert eng.pool.stats()["prefix_hit_rate"] > 0


def test_spec_sampled_smoke(model):
    """Sampled speculation runs end to end: right stream lengths, valid
    logprobs (distribution preservation is proven by the TV test)."""
    cfg, params = model
    sp = SamplingParams(temperature=0.7, top_k=0, top_p=1.0, seed=5)
    eng = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=2)
    rids = [eng.submit(p, max_new_tokens=5, sampling=sp) for p in PROMPTS]
    comps = eng.run()
    for r in rids:
        assert len(comps[r].tokens) == 5
        assert len(comps[r].logprobs) == 5
        assert all(lp <= 0.0 for lp in comps[r].logprobs)
        assert all(0 <= t < cfg.vocab_size for t in comps[r].tokens)


# ---------------------------------------------------------------------------
# Small fix: speculative bonus-token logprobs ride the existing batched
# finishing fetch — per-token logprobs (accepted drafts AND the bonus
# pick) match the plain dense engine's, with no extra transfer (the
# transfer count itself is pinned below).
# ---------------------------------------------------------------------------

def test_spec_logprobs_match_plain_single_fetch(model):
    cfg, params = model
    dense = Engine(cfg, params, max_len=48, batch_size=2,
                   decode_kernel="dense")
    drids = [dense.submit(p, max_new_tokens=5) for p in PROMPTS]
    dcomps = dense.run()
    spec = Engine(cfg, params, max_len=48, batch_size=2,
                  decode_kernel="fused", spec_k=3)
    srids = [spec.submit(p, max_new_tokens=5) for p in PROMPTS]
    scomps = spec.run()
    for dr, sr in zip(drids, srids):
        assert dcomps[dr].tokens == scomps[sr].tokens
        assert len(scomps[sr].logprobs) == len(scomps[sr].tokens)
        np.testing.assert_allclose(dcomps[dr].logprobs,
                                   scomps[sr].logprobs,
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Distribution contract: acceptance + residual sampling reproduces the
# target distribution exactly (speculative sampling's correctness
# theorem), driven through the very primitives the engine uses.
# ---------------------------------------------------------------------------

def test_accept_residual_marginal_matches_target():
    V, D, N = 13, 8, 8192
    C = jax.random.normal(jax.random.PRNGKey(2), (V, D))
    h = jax.random.normal(jax.random.PRNGKey(3), (D,))
    p = jax.nn.softmax(C @ h)
    d = int(jnp.argsort(p)[-2])                  # a plausible draft token

    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(N))
    tok, _, label_lp = sampling_mod.verify_tokens_fused(
        jnp.broadcast_to(h, (N, D)), C, keys,
        jnp.ones((N,)), jnp.zeros((N,), jnp.int32), jnp.ones((N,)),
        labels=jnp.full((N,), d, jnp.int32),
        exclude=jnp.full((N,), d, jnp.int32),
        vocab=V, with_filter=False)
    # the sweep's label score IS the target logprob of the draft
    np.testing.assert_allclose(label_lp, jnp.log(p[d]), rtol=1e-4)
    # the engine's acceptance uniform: same key, same salt
    u = jax.vmap(lambda k: jax.random.uniform(
        jax.random.fold_in(k, spec_mod._ACCEPT_SALT)))(keys)
    emitted = np.where(u < np.exp(label_lp), d, tok)
    # accepted-or-residual marginal == target softmax
    emp = np.bincount(emitted, minlength=V) / N
    tv = 0.5 * np.abs(emp - np.asarray(p)).sum()
    assert tv < 0.04, f"TV distance {tv:.4f} — residual sampling skewed"
    # the residual never re-emits the rejected draft
    assert not np.any(tok == d)
    # acceptance frequency tracks p(draft)
    acc = float(np.mean(u < np.exp(label_lp)))
    assert abs(acc - float(p[d])) < 0.02


def test_ngram_drafts_prompt_lookup():
    """The zero-cost drafter copies the continuation of the most recent
    earlier occurrence of the current token (and proposes 0 on a miss,
    to be rejected by verification)."""
    state = sched_mod.init_state(2, 8, 8, spec_k=3)
    state["prompt_buf"] = jnp.asarray(
        [[5, 6, 7, 5, 0, 0, 0, 0], [1, 2, 3, 4, 0, 0, 0, 0]], jnp.int32)
    state["prompt_len"] = jnp.asarray([4, 4], jnp.int32)
    state["n_out"] = jnp.asarray([0, 0], jnp.int32)
    state["tok"] = jnp.asarray([[5], [4]], jnp.int32)
    drafts = spec_mod.ngram_drafts(state, 3)
    # row 0: "5" last seen at index 0 -> continuation [6, 7, 5]
    assert drafts[0].tolist() == [6, 7, 5]
    # row 1: "4" never seen earlier -> null proposal
    assert drafts[1].tolist() == [0, 0, 0]


# ---------------------------------------------------------------------------
# KV rollback: a speculative round never touches the host-side page
# tables, refcounts, or prefix registry — rejected tails die on-device.
# ---------------------------------------------------------------------------

def test_spec_kvpool_rollback_invariants(model):
    cfg, params = model
    eng = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=3, kv_page_size=4)
    for p in PREFIX_PROMPTS[:2]:
        eng.submit(p, max_new_tokens=20)
    # run until both rows are mid-decode (prompts fully consumed)
    for _ in range(6):
        eng.step()
    pool = eng.pool
    snap = (copy.deepcopy(pool._rows), copy.deepcopy(pool._pending),
            pool.available_pages(), pool.stats())
    for _ in range(3):                  # speculative decode rounds, with
        eng.step()                      # (mostly) rejected draft tails
        pool.check_invariants()
    assert (copy.deepcopy(pool._rows), copy.deepcopy(pool._pending),
            pool.available_pages(), pool.stats()) == snap, (
        "a speculative decode round mutated host page state")
    eng.run()                           # drain; release must still work
    pool.check_invariants()
    # rows returned their private pages (published prefix pages may stay
    # resident in the registry for future reuse — that is the feature)
    assert pool.available_pages() >= snap[2]
    assert not pool._rows


# ---------------------------------------------------------------------------
# Host-sync discipline: speculation emits up to K+1 tokens per step for
# the SAME single unconditional device_get (2 on finishing steps), with
# or without metrics enabled.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_metrics", [False, True])
def test_spec_one_host_transfer_per_step(model, monkeypatch, with_metrics):
    cfg, params = model
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    kw = {"metrics": Registry()} if with_metrics else {}
    eng = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=3, **kw)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=5)
    calls.clear()
    n_steps = 0
    while eng.has_work():
        before = len(calls)
        done = eng.step()
        n_steps += 1
        assert len(calls) - before == (2 if done else 1), (
            "speculative telemetry added a host transfer")
    assert n_steps > 1


def test_spec_metrics_do_not_recompile_engine_step(model):
    from repro.serve import engine as engine_mod

    cfg, params = model
    Engine(cfg, params, max_len=48, batch_size=2, decode_kernel="fused",
           spec_k=3).generate(PROMPTS[:2], 3)           # warm the cache
    before = engine_mod._engine_step_spec._cache_size()
    eng = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=3, metrics=Registry())
    out = eng.generate(PROMPTS[:2], 3)
    assert engine_mod._engine_step_spec._cache_size() == before, \
        "enabling metrics recompiled the speculative engine step"
    assert out == Engine(cfg, params, max_len=48, batch_size=2,
                         decode_kernel="fused",
                         spec_k=3).generate(PROMPTS[:2], 3)


def test_spec_metrics_labels_and_telemetry(model):
    """ITL and step-wall carry the spec_k label; acceptance telemetry
    (histogram, counters, rate gauge) is emitted from the one existing
    sync — and is consistent with itself."""
    cfg, params = model
    mets = Registry()
    eng = Engine(cfg, params, max_len=48, batch_size=2,
                 decode_kernel="fused", spec_k=2, metrics=mets)
    eng.generate(PROMPTS, 5)
    itl = mets.histogram("serve_itl_seconds",
                         {"decode_kernel": "fused", "spec_k": 2})
    wall = mets.histogram("serve_step_wall_seconds",
                          {"decode_kernel": "fused", "spec_k": 2})
    assert itl.count > 0 and wall.count > 0
    acc = mets.histogram("serve_spec_accepted_len", {"spec_k": 2})
    drafted = mets.value("serve_spec_draft_tokens_total")
    emitted = mets.value("serve_spec_emitted_tokens_total")
    assert acc.count > 0
    # every decode round emits at least the bonus token; 4 requests x 5
    # tokens were produced in total, some via prefill boundary samples
    assert emitted == acc.sum and emitted <= 4 * 5
    assert 0 <= drafted <= acc.count * 2
    rate = mets.value("serve_spec_accept_rate")
    assert 0.0 <= rate <= 1.0


def test_spec_validation(model):
    cfg, params = model
    draft_params = T.init_lm(jax.random.PRNGKey(1), cfg)
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=48, batch_size=2, spec_k=-1)
    with pytest.raises(ValueError):                 # needs the fused path
        Engine(cfg, params, max_len=48, batch_size=2,
               decode_kernel="dense", spec_k=2)
    with pytest.raises(ValueError):                 # draft pair together
        Engine(cfg, params, max_len=48, batch_size=2,
               decode_kernel="fused", spec_k=2, draft_cfg=cfg)
    with pytest.raises(ValueError):                 # draft needs spec_k
        Engine(cfg, params, max_len=48, batch_size=2,
               decode_kernel="fused", draft_cfg=cfg,
               draft_params=draft_params)
    with pytest.raises(ValueError):                 # shared vocab only
        bad = _cfg(vocab_size=cfg.vocab_size * 2)
        Engine(cfg, params, max_len=48, batch_size=2,
               decode_kernel="fused", spec_k=2, draft_cfg=bad,
               draft_params=draft_params)
