"""Pallas WKV kernel vs the sequential oracle and the jnp chunked twin.

Interpret mode on CPU (the kernel body runs as JAX ops); shape/dtype sweep
per the kernel-testing contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.wkv import wkv_apply, wkv_forward_pallas
from repro.models import recurrent as R


def _inputs(b, h, s, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (b, h, s, hd)).astype(dtype)
               for i in range(3))
    w_log = -jnp.exp(jax.random.normal(ks[3], (b, h, s, hd)) - 2.0)
    u = jax.random.normal(ks[4], (h, hd)) * 0.5
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    return r, k, v, w_log.astype(jnp.float32), u.astype(jnp.float32), s0


@pytest.mark.parametrize("shape,chunk", [
    ((1, 2, 32, 8), 8),
    ((2, 2, 64, 16), 16),
    ((2, 4, 64, 8), 32),
    ((1, 1, 128, 32), 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv_kernel_matches_oracle(shape, chunk, dtype):
    b, h, s, hd = shape
    r, k, v, w_log, u, s0 = _inputs(b, h, s, hd, dtype)
    out, sf = wkv_forward_pallas(r, k, v, w_log, u, s0, chunk_len=chunk,
                                 block_g=min(2, b * h), interpret=True)
    o_ref, s_ref = ref.ref_wkv(r, k, v, w_log, u, s0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(s_ref),
                               atol=tol, rtol=tol)


def test_wkv_kernel_matches_jnp_twin():
    r, k, v, w_log, u, s0 = _inputs(2, 2, 64, 8, jnp.float32, seed=3)
    out, sf = wkv_forward_pallas(r, k, v, w_log, u, s0, chunk_len=16,
                                 block_g=4, interpret=True)
    o_twin, s_twin = R._rwkv6_chunk(r, k, v, w_log, u, s0, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_twin),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(s_twin),
                               atol=1e-4, rtol=1e-4)


def test_wkv_apply_gradients_match_twin():
    r, k, v, w_log, u, s0 = _inputs(1, 2, 32, 8, jnp.float32, seed=7)
    g = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 32, 8))

    def loss_kernel(r, k, v, w_log, u):
        out, _ = wkv_apply(r, k, v, w_log, u, s0, 8, True)
        return jnp.sum(out * g)

    def loss_twin(r, k, v, w_log, u):
        out, _ = R._rwkv6_chunk(r, k, v, w_log, u, s0, 8)
        return jnp.sum(out * g)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(r, k, v, w_log, u)
    gt = jax.grad(loss_twin, argnums=(0, 1, 2, 3, 4))(r, k, v, w_log, u)
    for a, b_, name in zip(gk, gt, "r k v w u".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


def test_wkv_kernel_long_context_state_passing():
    """Chunked state hand-off across many chunks stays exact (long_500k
    family property, scaled down)."""
    r, k, v, w_log, u, s0 = _inputs(1, 1, 256, 8, jnp.float32, seed=11)
    out64, sf64 = wkv_forward_pallas(r, k, v, w_log, u, s0, chunk_len=64,
                                     block_g=1, interpret=True)
    out8, sf8 = wkv_forward_pallas(r, k, v, w_log, u, s0, chunk_len=8,
                                   block_g=1, interpret=True)
    np.testing.assert_allclose(np.asarray(out64), np.asarray(out8),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf64), np.asarray(sf8),
                               atol=1e-4, rtol=1e-4)
