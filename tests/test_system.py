"""End-to-end behaviour tests for the CCE training system."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.analysis import hlo as hlo_an
from repro.configs.base import TrainConfig
from repro.train import Trainer


def test_training_decreases_loss_cce_head():
    # 120 steps: the reduced gemma cell (tied embeddings + sqrt(d) embed
    # scaling) needs ~100 steps before the Markov structure shows up in the
    # loss; all loss impls (cce/cce_jax/dense) track each other exactly, so
    # the horizon only buys signal-to-noise, not numerics slack.
    cfg = dataclasses.replace(configs.get_reduced_config("gemma_2b"),
                              dtype="float32", loss_impl="cce")
    tcfg = TrainConfig(total_steps=120, warmup_steps=5, learning_rate=1e-3)
    tr = Trainer(cfg, tcfg, seq_len=32, global_batch=4)
    hist = tr.run(num_steps=120, log_every=10, log_fn=None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_cce_and_dense_training_converge_identically():
    """The paper's Fig. 4 claim at smoke scale: loss curves match."""
    def run(loss_impl):
        cfg = dataclasses.replace(configs.get_reduced_config("llama3_2_3b"),
                                  dtype="float32", loss_impl=loss_impl)
        tcfg = TrainConfig(total_steps=25, warmup_steps=2,
                           learning_rate=1e-3, seed=7)
        tr = Trainer(cfg, tcfg, seq_len=32, global_batch=4)
        return [h["loss"] for h in tr.run(num_steps=25, log_every=5,
                                          log_fn=None)]

    a = run("cce")
    b = run("dense")
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_hlo_analyzer_counts_scan_flops_exactly():
    D, L, B = 32, 5, 4

    def model(params, x):
        h, _ = jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), x, params)
        return h.sum()

    comp = jax.jit(model).lower(jnp.zeros((L, D, D)),
                                jnp.zeros((B, D))).compile()
    res = hlo_an.analyze(comp.as_text())
    assert res["flops"] == 2 * B * D * D * L


def test_hlo_analyzer_finds_collectives_in_text():
    txt = """
HloModule m, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  ROOT %ar = f32[8,16]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    res = hlo_an.analyze(txt)
    assert res["collective_bytes"] == 8 * 16 * 4
    assert res["collective_counts"] == {"all-reduce": 1}
    # ring all-reduce wire bytes: 2*b*(g-1)/g
    assert abs(res["collective_wire_bytes"]
               - 2 * 8 * 16 * 4 * 3 / 4) < 1e-6


def test_serve_engine_generates():
    from repro.serve.engine import Engine
    cfg = dataclasses.replace(configs.get_reduced_config("llama3_2_3b"),
                              dtype="float32")
    from repro.models import transformer as T
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    prompts = [[1, 2, 3], [4, 5]]
    out = eng.generate(prompts, max_new_tokens=6)
    assert len(out) == 2
    assert all(len(o) == 6 for o in out)
    # greedy decoding is deterministic
    out2 = eng.generate(prompts, max_new_tokens=6)
    assert out == out2
