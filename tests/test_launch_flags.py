"""Regression tests: the ``--cce-*`` CLI surface tracks CCEConfig.

``launch/cce_flags.py`` auto-derives flags from the dataclass; these tests
pin that every field added for the fused backward / bitmap filtering work
(``bwd``, ``filter_stats``) is reachable end-to-end through the real
``launch/train`` and ``launch/dryrun`` entry points (argv -> argparse ->
CCEConfig -> Trainer / run_cell), and that invalid values are rejected at
the CLI boundary.
"""

import argparse
import dataclasses

import pytest

from repro.kernels.ops import CCEConfig
from repro.launch.cce_flags import _FLAGS, add_cce_args, cce_config_from_args


def test_every_new_knob_has_a_flag():
    covered = {field for field, _ in _FLAGS.values()}
    assert {"bwd", "filter_stats"} <= covered
    fields = {f.name for f in dataclasses.fields(CCEConfig)}
    assert covered <= fields  # _validate_flags would raise too


def test_parse_new_knobs_roundtrip():
    ap = argparse.ArgumentParser()
    add_cce_args(ap)
    c = cce_config_from_args(ap.parse_args(
        ["--cce-bwd", "two_pass", "--cce-filter-stats", "recompute"]))
    assert c.bwd == "two_pass" and c.filter_stats == "recompute"
    # unset flags keep dataclass defaults (measured best: fused+fwd_bitmap)
    c2 = cce_config_from_args(ap.parse_args(["--cce-sort-vocab"]))
    assert c2.bwd == "fused" and c2.filter_stats == "fwd_bitmap"
    assert cce_config_from_args(ap.parse_args([])) is None


@pytest.mark.parametrize("argv", [
    ["--cce-bwd", "single_pass"],
    ["--cce-filter-stats", "oracle"],
])
def test_cli_rejects_invalid_values(argv):
    ap = argparse.ArgumentParser()
    add_cce_args(ap)
    with pytest.raises(SystemExit):
        ap.parse_args(argv)


def test_train_cli_threads_cce_config(monkeypatch):
    """argv -> launch.train.main -> Trainer(cce_cfg=...) end-to-end, with
    the Trainer stubbed so no training runs."""
    from repro.launch import train as train_cli

    seen = {}

    class FakeTrainer:
        def __init__(self, cfg, tcfg, **kw):
            seen.update(kw)

        def install_signal_handlers(self):
            pass

        def run(self, num_steps=None, **kw):
            pass

        def save(self):
            pass

    monkeypatch.setattr(train_cli, "Trainer", FakeTrainer)
    monkeypatch.setattr(
        "sys.argv",
        ["train", "--arch", "gemma_2b", "--reduced", "--steps", "1",
         "--batch", "2", "--seq", "16",
         "--cce-bwd", "two_pass", "--cce-filter-stats", "recompute",
         "--cce-sort-vocab"])
    train_cli.main()
    c = seen["cce_cfg"]
    assert isinstance(c, CCEConfig)
    assert c.bwd == "two_pass" and c.filter_stats == "recompute"
    assert c.sort_vocab

    monkeypatch.setattr(
        "sys.argv",
        ["train", "--arch", "gemma_2b", "--reduced", "--steps", "1",
         "--cce-bwd", "bogus"])
    with pytest.raises(SystemExit):
        train_cli.main()


def test_dryrun_cli_threads_cce_config(monkeypatch):
    """argv -> launch.dryrun.main -> run_cell(cce_cfg=...) end-to-end,
    with run_cell stubbed so nothing compiles."""
    from repro.launch import dryrun as dryrun_cli

    seen = []

    def fake_run_cell(arch, shape, multi_pod, out_dir, *, force=False,
                      loss_impl=None, tag="", cce_cfg=None):
        seen.append(cce_cfg)
        return {"ok": True, "compile_s": 0.0, "roofline": {}}

    monkeypatch.setattr(dryrun_cli, "run_cell", fake_run_cell)
    monkeypatch.setattr(
        "sys.argv",
        ["dryrun", "--arch", "gemma_2b", "--shape", "train_4k",
         "--mesh", "single", "--cce-bwd", "fused",
         "--cce-filter-stats", "fwd_bitmap", "--cce-accum", "f32"])
    with pytest.raises(SystemExit) as e:
        dryrun_cli.main()
    assert e.value.code == 0
    assert seen and all(isinstance(c, CCEConfig) for c in seen)
    assert seen[0].bwd == "fused" and seen[0].filter_stats == "fwd_bitmap"

    monkeypatch.setattr(
        "sys.argv", ["dryrun", "--cce-filter-stats", "nope"])
    with pytest.raises(SystemExit) as e:
        dryrun_cli.main()
    assert e.value.code != 0
