"""Tests for kernels/decode_sample.py — the fused projection->sample
(logit-free decode) kernel and its pure-JAX reference twin.

The twin is the CPU execution path and the Pallas kernel (interpret mode
here) must be *token-identical* to it: both run the same per-tile math
and the same counter-based hash noise, so every divergence is a bug, not
tolerance. Distributional correctness is pinned against
``jax.random.categorical``; the top-k/top-p histogram thresholds are
checked against the conservative-superset contract of DESIGN.md §10.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_sample as ds
from repro.kernels.ops import _VMEM_BUDGET


def _problem(b=8, d=64, vpad=512, vocab=500, seed=0):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((vpad, d)), jnp.float32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    return h, C, keys


MIXED_TEMP = jnp.asarray([0.0, 1.0, 0.7, 0.0, 1.3, 1.0, 0.5, 2.0])
MIXED_TOPK = jnp.asarray([0, 0, 5, 0, 50, 0, 3, 10], jnp.int32)
MIXED_TOPP = jnp.asarray([1.0, 0.9, 1.0, 1.0, 0.95, 1.0, 1.0, 0.8])


# ---------------------------------------------------------------------------
# Kernel == twin (bit-exact tokens, close logprobs).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_filter", [False, True])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_kernel_matches_ref_twin(with_filter, softcap):
    h, C, keys = _problem()
    tk = MIXED_TOPK if with_filter else jnp.zeros(8, jnp.int32)
    tp = MIXED_TOPP if with_filter else jnp.ones(8)
    t_ref, l_ref = ds.decode_sample_ref(
        h, C, keys, MIXED_TEMP, tk, tp, vocab=500, softcap=softcap,
        with_filter=with_filter, block_v=128)
    t_ker, l_ker = ds.decode_sample_pallas(
        h, C, keys, MIXED_TEMP, tk, tp, vocab=500, softcap=softcap,
        with_filter=with_filter, block_b=8, block_v=128, interpret=True)
    np.testing.assert_array_equal(t_ref, t_ker)
    np.testing.assert_allclose(l_ref, l_ker, rtol=1e-5, atol=1e-5)


def test_twin_row_chunking_is_invisible():
    """The twin processes rows in block_b chunks (lax.map); a non-multiple
    row count and different chunk sizes must not change any row."""
    h, C, keys = _problem(b=12)
    temp = jnp.asarray([0.0, 0.9] * 6)
    tk = jnp.asarray([0, 7] * 6, jnp.int32)
    tp = jnp.asarray([1.0, 0.85] * 6)
    a = ds.decode_sample_ref(h, C, keys, temp, tk, tp, vocab=500,
                             block_v=128, block_b=8)
    b_ = ds.decode_sample_ref(h, C, keys, temp, tk, tp, vocab=500,
                              block_v=128, block_b=4)
    np.testing.assert_array_equal(a[0], b_[0])
    np.testing.assert_allclose(a[1], b_[1], rtol=1e-6)


def test_block_v_is_invisible():
    """The online-LSE / running-max recurrences must not depend on the
    vocab tiling: tokens are identical across block_v choices."""
    h, C, keys = _problem()
    outs = [ds.decode_sample_ref(h, C, keys, MIXED_TEMP, MIXED_TOPK,
                                 MIXED_TOPP, vocab=500, block_v=bv)[0]
            for bv in (128, 256, 512)]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


# ---------------------------------------------------------------------------
# Greedy: token-identical to the dense argmax, logprob = log_softmax.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("softcap", [None, 20.0])
def test_greedy_matches_dense(softcap):
    h, C, keys = _problem()
    logits = h @ C.T
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(jnp.arange(512) < 500, logits, -jnp.inf)
    zero = jnp.zeros(8)
    tok, lp = ds.decode_sample(
        h, C, keys, zero, jnp.zeros(8, jnp.int32), jnp.ones(8),
        vocab=500, softcap=softcap, with_filter=False)
    np.testing.assert_array_equal(tok, jnp.argmax(logits, axis=1))
    want = jax.nn.log_softmax(logits, axis=1)[jnp.arange(8), tok]
    np.testing.assert_allclose(lp, want, rtol=1e-4, atol=1e-4)


def test_greedy_unaffected_by_filter_params():
    """Greedy rows (temperature 0) ignore top-k/top-p entirely — the
    stats sweep runs their LSE on raw logits and the argmax is always in
    the kept set."""
    h, C, keys = _problem()
    zero = jnp.zeros(8)
    base, base_lp = ds.decode_sample_ref(
        h, C, keys, zero, jnp.zeros(8, jnp.int32), jnp.ones(8),
        vocab=500, with_filter=False)
    filt, filt_lp = ds.decode_sample_ref(
        h, C, keys, zero, jnp.full((8,), 3, jnp.int32), jnp.full((8,), .5),
        vocab=500, with_filter=True)
    np.testing.assert_array_equal(base, filt)
    np.testing.assert_allclose(base_lp, filt_lp, rtol=1e-5, atol=1e-5)


def test_greedy_with_sample_off_fast_path():
    """``with_sample=False`` (the static all-greedy engine fast path —
    no noise hash, no Gumbel recurrence, no scaled-logit copy) must be
    output-identical to the default path on an all-greedy batch, in both
    the twin and the interpret-mode kernel."""
    h, C, keys = _problem()
    zero = jnp.zeros(8)
    tk0 = jnp.zeros(8, jnp.int32)
    tp1 = jnp.ones(8)
    base = ds.decode_sample_ref(h, C, keys, zero, tk0, tp1, vocab=500,
                                with_filter=False, block_v=128)
    fast = ds.decode_sample_ref(h, C, keys, zero, tk0, tp1, vocab=500,
                                with_sample=False, block_v=128)
    kfast = ds.decode_sample_pallas(h, C, keys, zero, tk0, tp1, vocab=500,
                                    with_sample=False, block_b=8,
                                    block_v=128, interpret=True)
    np.testing.assert_array_equal(base[0], fast[0])
    np.testing.assert_allclose(base[1], fast[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(base[0], kfast[0])
    np.testing.assert_allclose(base[1], kfast[1], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Streaming Gumbel-max: same distribution as jax.random.categorical.
# ---------------------------------------------------------------------------

def test_gumbel_matches_categorical_distribution():
    """Empirical total-variation distance of the fused sampler from the
    true softmax must match jax.random.categorical's at the same sample
    count (both are fixed-seed, so this is deterministic)."""
    rng = np.random.default_rng(1)
    d, v, n, tau = 32, 256, 4000, 0.9
    h1 = jnp.asarray(rng.standard_normal((1, d)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    logits = (h1 @ C.T)[0]
    p = np.asarray(jax.nn.softmax(logits / tau))

    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n))
    tok, _ = jax.jit(lambda *a: ds.decode_sample_ref(
        *a, vocab=v, with_filter=False, block_v=128))(
        jnp.tile(h1, (n, 1)), C, keys, jnp.full((n,), tau),
        jnp.zeros((n,), jnp.int32), jnp.ones((n,)))
    emp = np.bincount(np.asarray(tok), minlength=v) / n
    cat = jax.vmap(lambda k: jax.random.categorical(k, logits / tau))(keys)
    emp_cat = np.bincount(np.asarray(cat), minlength=v) / n

    tv_fused = 0.5 * np.abs(emp - p).sum()
    tv_cat = 0.5 * np.abs(emp_cat - p).sum()
    assert tv_fused <= tv_cat + 0.02, (tv_fused, tv_cat)


def test_sampled_streams_deterministic_and_row_keyed():
    """Same keys -> same tokens; distinct row keys -> (overwhelmingly)
    distinct streams even for identical rows."""
    h, C, keys = _problem()
    h = jnp.tile(h[:1], (8, 1))          # identical rows, distinct keys
    temp = jnp.full((8,), 1.0)
    a = ds.decode_sample_ref(h, C, keys, temp, jnp.zeros(8, jnp.int32),
                             jnp.ones(8), vocab=500, with_filter=False)
    b = ds.decode_sample_ref(h, C, keys, temp, jnp.zeros(8, jnp.int32),
                             jnp.ones(8), vocab=500, with_filter=False)
    np.testing.assert_array_equal(a[0], b[0])
    assert len(set(np.asarray(a[0]).tolist())) > 1


# ---------------------------------------------------------------------------
# top-k / top-p: conservative-superset contract (DESIGN.md §10).
# ---------------------------------------------------------------------------

def test_topk_topp_superset_contract():
    """Every sampled token lies within width/n_buckets of the exact
    filter cutoff — the kept set is a superset of the exact top-k/top-p
    set, never tighter."""
    rng = np.random.default_rng(3)
    b, d, v = 8, 32, 256
    h = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    temp = jnp.full((b,), 0.8)
    tk = jnp.asarray([1, 2, 5, 10, 0, 3, 50, 0], jnp.int32)
    tp = jnp.asarray([1.0, 1.0, 1.0, 1.0, 0.7, 0.9, 1.0, 0.5])
    scaled = np.asarray((h @ C.T) / 0.8)
    for trial in range(50):
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.arange(b) + 1000 + trial * b)
        tok, _ = ds.decode_sample_ref(h, C, keys, temp, tk, tp, vocab=v,
                                      with_filter=True, block_v=128)
        for r in range(b):
            srow, t = scaled[r], int(tok[r])
            order = np.argsort(-srow)
            width = srow.max() - max(
                srow.min(), jax.nn.logsumexp(srow) + np.log(1e-9))
            slack = width / ds.DEFAULT_BUCKETS
            if int(tk[r]) > 0:
                kth = srow[order[int(tk[r]) - 1]]
                assert srow[t] >= kth - slack, (r, t)
            if float(tp[r]) < 1.0:
                cum = np.cumsum(np.asarray(jax.nn.softmax(srow))[order])
                j = int(np.searchsorted(cum, float(tp[r])))
                assert srow[t] >= srow[order[min(j, v - 1)]] - slack, (r, t)


def test_top_k_one_pins_argmax():
    """top_k=1 must always return the scaled argmax (the argmax is kept
    by construction and nothing else survives the threshold)."""
    h, C, keys = _problem(seed=5)
    temp = jnp.full((8,), 2.0)
    tok, _ = ds.decode_sample_ref(
        h, C, keys, temp, jnp.ones(8, jnp.int32), jnp.ones(8),
        vocab=500, with_filter=True, block_v=128)
    logits = jnp.where(jnp.arange(512) < 500, h @ C.T, -jnp.inf)
    np.testing.assert_array_equal(tok, jnp.argmax(logits, axis=1))


# ---------------------------------------------------------------------------
# Block accounting.
# ---------------------------------------------------------------------------

def test_choose_decode_blocks_fits_budget():
    for batch, vocab, d in [(8, 32768, 64), (32, 131072, 4096),
                            (512, 262144, 8192)]:
        for wf in (False, True):
            bb, bv = ds.choose_decode_blocks(batch, vocab, d, 4,
                                             with_filter=wf)
            assert bb % 8 == 0 and bv % 128 == 0
            assert ds.decode_vmem_working_set(
                bb, bv, d, 4, with_filter=wf) <= _VMEM_BUDGET


def test_filtered_budget_is_tighter():
    """The histogram scratch (rank-3 one-hot + two histograms) must be
    charged: the filtered working set strictly exceeds the unfiltered one
    at the same blocks."""
    assert (ds.decode_vmem_working_set(8, 512, 4096, 4, with_filter=True)
            > ds.decode_vmem_working_set(8, 512, 4096, 4,
                                         with_filter=False))
