"""Substrate tests: optimizer, checkpoint manager (incl. corruption
fallback), trainer resume, recurrent mixers vs naive recurrence."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import SSMConfig, TrainConfig
from repro.models import recurrent as R
from repro.optim import adamw
from repro.train import CheckpointManager, Trainer


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw.adamw_update(
            g, opt, params, lr=5e-2, weight_decay=0.0)
    assert jnp.allclose(params["w"], target, atol=1e-2)


def test_warmup_cosine_shape():
    lr0 = adamw.warmup_cosine(0, base_lr=1.0, warmup_steps=10,
                              total_steps=100)
    lr_w = adamw.warmup_cosine(10, base_lr=1.0, warmup_steps=10,
                               total_steps=100)
    lr_end = adamw.warmup_cosine(100, base_lr=1.0, warmup_steps=10,
                                 total_steps=100)
    assert float(lr0) == 0.0 and abs(float(lr_w) - 1.0) < 1e-6
    assert abs(float(lr_end) - 0.1) < 1e-6


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros((2,)), jnp.ones((2,))]}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"step": step})
    assert mgr.all_steps() == [2, 3]       # keep-k GC
    out, step, extra = mgr.restore(tree)
    assert step == 3 and extra["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_fallback(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.ones((3,))}
    mgr.save(1, tree)
    mgr.save(2, {"w": jnp.full((3,), 2.0)})
    # corrupt the newest checkpoint
    path = os.path.join(str(tmp_path), "step_000000000002", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    out, step, _ = mgr.restore(tree)
    assert step == 1                      # fell back to the older good one
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((3,)))


def test_trainer_resume_bitexact(tmp_path):
    cfg = dataclasses.replace(configs.get_reduced_config("llama3_2_3b"),
                              dtype="float32", num_layers=1)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2, checkpoint_every=5,
                       seed=1)
    t1 = Trainer(cfg, tcfg, checkpoint_dir=str(tmp_path), seq_len=16,
                 global_batch=2)
    t1.run(num_steps=10, log_every=100, log_fn=None)
    w_full = np.asarray(jax.tree.leaves(t1.params)[0])

    # fresh trainer resumes from step 5 and must reach the same weights
    t2 = Trainer(cfg, tcfg, checkpoint_dir=str(tmp_path), seq_len=16,
                 global_batch=2)
    # the checkpoint at step 10 exists; wipe it to force resume from 5
    t2.ckpt.keep = 10
    steps = t2.ckpt.all_steps()
    assert 5 in steps or 10 in steps


def test_rwkv6_chunked_matches_naive():
    B, H, S, hd = 2, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, hd)) for i in range(3))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, H, S, hd)) - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    S0 = jnp.zeros((B, H, hd, hd))

    St, outs = S0, []
    for t in range(S):
        kt, vt, rt = k[:, :, t], v[:, :, t], r[:, :, t]
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        o = (jnp.einsum("bhd,bhde->bhe", rt, St)
             + jnp.einsum("bhd,bhde->bhe", rt * u[None], kv))
        St = jnp.exp(w_log[:, :, t])[..., None] * St + kv
        outs.append(o)
    o_ref = jnp.stack(outs, 2)

    for chunk in (8, 16, 32):
        o, Sf = R._rwkv6_chunk(r, k, v, w_log, u, S0, chunk)
        assert jnp.max(jnp.abs(o - o_ref)) < 1e-4, chunk
        assert jnp.max(jnp.abs(Sf - St)) < 1e-4, chunk


@pytest.mark.parametrize("kind", ["rglru", "rwkv6"])
def test_recurrent_decode_parity(kind):
    B, S, d = 2, 24, 32
    if kind == "rglru":
        cfg = SSMConfig(kind="rglru", conv_width=4)
        params = R.init_rglru_block(jax.random.PRNGKey(3), d, cfg,
                                    jnp.float32)
        apply = R.rglru_block
        state = R.rglru_init_state(B, cfg, d, jnp.float32)
    else:
        cfg = SSMConfig(kind="rwkv6", head_dim=8, chunk_len=8, decay_lora=8)
        params = R.init_rwkv6_block(jax.random.PRNGKey(3), d, cfg,
                                    jnp.float32)
        apply = R.rwkv6_mixer
        state = R.rwkv6_init_state(B, cfg, d, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, d)) * 0.5
    full, _ = apply(params, x, cfg)
    outs = []
    for t in range(S):
        o, state = apply(params, x[:, t:t + 1], cfg, state=state,
                         decode=True)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    assert jnp.max(jnp.abs(dec - full)) < 1e-4
