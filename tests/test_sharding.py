"""Multi-device tests (8 forced host devices, run in subprocesses because
jax locks the device count at first init — the main pytest process must
keep seeing one device)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_vocab_parallel_cce_matches_oracle():
    out = _run("""
import jax, jax.numpy as jnp
from repro.core import vocab_parallel_cross_entropy
from repro.kernels import ref
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4), ("data", "model"))
ks = jax.random.split(jax.random.PRNGKey(0), 3)
E = jax.random.normal(ks[0], (64, 32)) * 0.7
C = jax.random.normal(ks[1], (512, 32)) * 0.5
x = jax.random.randint(ks[2], (64,), 0, 512)
g = jax.random.normal(jax.random.PRNGKey(9), (64,))
for impl in ("cce_jax", "cce"):
    def loss(e, c):
        return jnp.sum(vocab_parallel_cross_entropy(
            e, c, x, mesh=mesh, impl=impl) * g)
    nll = vocab_parallel_cross_entropy(E, C, x, mesh=mesh, impl=impl)
    dE, dC = jax.grad(loss, argnums=(0, 1))(E, C)
    dEr, dCr = ref.ref_grads(E, C, x, g=g)
    assert float(jnp.max(jnp.abs(nll - ref.ref_linear_cross_entropy(E, C, x)))) < 1e-4
    assert float(jnp.max(jnp.abs(dE - dEr))) < 1e-4
    assert float(jnp.max(jnp.abs(dC - dCr))) < 1e-4
print("OK")
""")
    assert "OK" in out


def test_vocab_parallel_lse_pick_sum_matches_dense():
    """The third (sum_logits) output distributes as one psum; registry
    losses built on it (label smoothing) match the single-device dense
    reference under the vocab-parallel combine."""
    out = _run("""
import jax, jax.numpy as jnp
from repro.core import lse_and_pick
from repro.core.vocab_parallel import vocab_parallel_lse_pick
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4), ("data", "model"))
ks = jax.random.split(jax.random.PRNGKey(0), 3)
E = jax.random.normal(ks[0], (64, 32)) * 0.7
C = jax.random.normal(ks[1], (512, 32)) * 0.5
x = jax.random.randint(ks[2], (64,), 0, 512)
ref = lse_and_pick(E, C, x, impl="dense", with_sum_logits=True)
for impl in ("cce_jax", "cce"):
    outs = vocab_parallel_lse_pick(E, C, x, mesh=mesh, impl=impl,
                                   with_sum_logits=True)
    for name, a, b in zip(("lse", "pick", "sum"), outs, ref):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 1e-4, (impl, name, err)
    # gradients of a label-smoothing-style functional of all three outputs
    def loss(e, c, impl=impl):
        lse, pick, zs = vocab_parallel_lse_pick(e, c, x, mesh=mesh,
                                                impl=impl,
                                                with_sum_logits=True)
        return jnp.sum(0.9 * (lse - pick) + 0.1 * (lse - zs / 512))
    def loss_ref(e, c):
        lse, pick, zs = lse_and_pick(e, c, x, impl="dense",
                                     with_sum_logits=True)
        return jnp.sum(0.9 * (lse - pick) + 0.1 * (lse - zs / 512))
    dE, dC = jax.grad(loss, argnums=(0, 1))(E, C)
    dEr, dCr = jax.grad(loss_ref, argnums=(0, 1))(E, C)
    assert float(jnp.max(jnp.abs(dE - dEr))) < 1e-4
    assert float(jnp.max(jnp.abs(dC - dCr))) < 1e-4
print("OK")
""")
    assert "OK" in out


@pytest.mark.xfail(
    strict=False,
    reason="XLA GSPMD wrong-result (see CHANGES.md PR 1 root cause): when "
           "the GQA kv-projection output is sharded and num_kv_heads (2 in "
           "the reduced config) does not divide the model axis (4), the "
           "sharded forward diverges by O(1) with only wk sharded. Not a "
           "repo bug — params after the optimizer step still match "
           "bit-exactly on a single-layer repro, and the vocab-parallel "
           "oracle tests pass.")
def test_sharded_train_step_matches_single_device():
    """One optimizer step on the 2x4 mesh equals the unsharded step."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
import repro.configs as configs
from repro.configs.base import TrainConfig
from repro.models import transformer as T
from repro.optim import adamw
from repro.train.trainer import make_train_step
from repro.sharding.specs import named, param_specs
from repro.sharding import make_rules, use_sharding_rules

cfg = dataclasses.replace(configs.get_reduced_config("llama3_2_3b"),
                          dtype="float32", loss_impl="cce_jax")
tcfg = TrainConfig()
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4), ("data", "model"))
params = T.init_lm(jax.random.PRNGKey(0), cfg)
opt = adamw.adamw_init(params)
ks = jax.random.split(jax.random.PRNGKey(1), 2)
batch = {"tokens": jax.random.randint(ks[0], (4, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (4, 32), 0, cfg.vocab_size)}
step = make_train_step(cfg, tcfg)
p1, o1, m1 = jax.jit(step)(params, opt, batch, 0)

p_specs = named(mesh, param_specs(cfg, params, mesh))
params_sh = jax.device_put(params, p_specs)
with use_sharding_rules(make_rules(mesh)):
    p2, o2, m2 = jax.jit(step)(params_sh, opt, batch, 0)
err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
          for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert err < 1e-4, err
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
print("OK", err)
""")
    assert "OK" in out


@pytest.mark.parametrize("arch", ["gemma_2b", "olmoe_1b_7b", "rwkv6_3b",
                                  "seamless_m4t_medium"])
def test_mini_dryrun_cell(arch):
    """Reduced-config dry-run on a (2,2,2) pod mesh: lower+compile+roofline
    must succeed for train and decode kinds."""
    out = _run(f"""
import jax
import repro.configs as configs
import repro.launch.mesh as mesh_mod
import repro.launch.dryrun as dr
from repro.configs.base import ShapeConfig
import repro.configs.base as base

def small_mesh(*, multi_pod=False):
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(shape, axes)
mesh_mod.make_production_mesh = small_mesh
dr.make_production_mesh = small_mesh
configs.get_config = configs.get_reduced_config
base.SHAPES["mini_train"] = ShapeConfig("mini_train", 64, 8, "train")
base.SHAPES["mini_decode"] = ShapeConfig("mini_decode", 128, 8, "decode")
dr.SHAPES = base.SHAPES
import tempfile
with tempfile.TemporaryDirectory() as d:
    for shape in ("mini_train", "mini_decode"):
        for mp in (False, True):
            rec = dr.run_cell("{arch}", shape, mp, d, force=True)
            assert rec["ok"], rec.get("error")
            if not rec.get("skipped"):
                assert rec["roofline"]["hlo_flops"] > 0
print("OK")
""")
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Checkpoint written under one mesh restores onto a different mesh
    (elastic restart: arrays stored unsharded, re-sharded on load)."""
    out = _run("""
import tempfile
import jax, jax.numpy as jnp, numpy as np
import repro.configs as configs
from repro.models import transformer as T
from repro.sharding.specs import named, param_specs
from repro.train.checkpoint import CheckpointManager

cfg = configs.get_reduced_config("llama3_2_3b")
params = T.init_lm(jax.random.PRNGKey(0), cfg)

from repro.launch.mesh import make_test_mesh
mesh_a = make_test_mesh((2, 4), ("data", "model"))
mesh_b = make_test_mesh((4, 2), ("data", "model"))

sharded_a = jax.device_put(params, named(mesh_a, param_specs(cfg, params, mesh_a)))
with tempfile.TemporaryDirectory() as d:
    ckpt = CheckpointManager(d, keep=2)
    ckpt.save(7, {"params": sharded_a})
    tree, step, extra = ckpt.restore({"params": params})
    assert step == 7, step
    # re-shard onto the *different* mesh and verify value equality
    sharded_b = jax.device_put(tree["params"],
                               named(mesh_b, param_specs(cfg, params, mesh_b)))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(sharded_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK")
""")
    assert "OK" in out
