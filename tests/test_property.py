"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import linear_cross_entropy
from repro.data.synthetic import (DataConfig, SyntheticLM, pack_documents,
                                  packed_labels)
from repro.kernels import CCEConfig, linear_cross_entropy_pallas
from repro.kernels import ref
from repro.optim import adamw


def _problem(seed, n, d, v):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    E = jax.random.normal(ks[0], (n, d)) * 0.5
    C = jax.random.normal(ks[1], (v, d)) * 0.5
    x = jax.random.randint(ks[2], (n,), 0, v)
    return E, C, x


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), shift=st.floats(-5, 5),
       n=st.sampled_from([8, 17, 32]), v=st.sampled_from([128, 200, 256]))
def test_cce_shift_invariance(seed, shift, n, v):
    """nll is invariant to adding a constant column to the classifier bias
    structure: shifting ALL logits of a token (adding s to E's projection
    via C -> logits+s) leaves softmax CE unchanged. We emulate by appending
    a constant feature."""
    E, C, x = _problem(seed, n, 16, v)
    E2 = jnp.concatenate([E, jnp.ones((n, 1))], 1)
    C2 = jnp.concatenate([C, jnp.full((v, 1), shift)], 1)
    cfg = CCEConfig(block_n=8, block_v=128)
    a = linear_cross_entropy_pallas(E2, C2, x, cfg)
    b = ref.ref_linear_cross_entropy(E, C, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_cce_vocab_permutation_equivariance(seed):
    """Permuting the vocabulary (and labels accordingly) leaves the loss
    unchanged and permutes dC accordingly."""
    E, C, x = _problem(seed, 16, 16, 128)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), 128)
    inv = jnp.argsort(perm)
    cfg = CCEConfig(block_n=8, block_v=128)
    nll1 = linear_cross_entropy_pallas(E, C, x, cfg)
    nll2 = linear_cross_entropy_pallas(E, C[perm], inv[x], cfg)
    np.testing.assert_allclose(np.asarray(nll1), np.asarray(nll2),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_nll_exceeds_label_margin_bound(seed):
    """0 <= nll and nll >= logsumexp bound: nll_i >= log(1) = 0, with
    equality only if the label holds all probability mass."""
    E, C, x = _problem(seed, 24, 16, 128)
    nll = ref.ref_linear_cross_entropy(E, C, x)
    assert np.all(np.asarray(nll) >= -1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_impl_equivalence(seed):
    """All five implementations agree on the mean loss."""
    E, C, x = _problem(seed, 32, 16, 160)
    ms = []
    for impl in ("cce", "cce_jax", "dense", "chunked"):
        nll = linear_cross_entropy(E, C, x, impl=impl)
        ms.append(float(jnp.mean(nll)))
    ms.append(float(linear_cross_entropy(E, C, x, impl="liger",
                                         reduction="mean")))
    np.testing.assert_allclose(ms, ms[0], rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10**6), shards=st.sampled_from([1, 2, 4, 8]))
def test_data_determinism_and_sharding(step, shards):
    """batch_at is pure in step; shards tile the global batch exactly."""
    data = SyntheticLM(DataConfig(vocab_size=512, seq_len=16,
                                  global_batch=8, seed=3))
    b1 = data.batch_at(step)
    b2 = data.batch_at(step)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    got = np.concatenate([data.shard_batch(b1, i, shards)["tokens"]
                          for i in range(shards)])
    assert np.array_equal(got, b1["tokens"])


@settings(max_examples=20, deadline=None)
@given(lengths=st.lists(st.integers(1, 64), min_size=1, max_size=40),
       seq=st.sampled_from([64, 128]))
def test_packing_conservation(lengths, seq):
    """Packing never drops tokens, never overlaps, never exceeds rows."""
    rows = pack_documents(lengths, seq)
    placed = sorted(d for row in rows for (d, _, _) in row)
    assert placed == sorted(range(len(lengths)))
    for row in rows:
        spans = sorted((s, s + ln) for (_, s, ln) in row)
        assert spans[-1][1] <= seq
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b <= c  # no overlap
    valid = packed_labels(rows, seq)
    assert valid.sum() == sum(min(l, seq) - 1 for l in lengths)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), clip=st.floats(0.1, 2.0))
def test_grad_clip_bounds_norm(seed, clip):
    tree = {"a": jax.random.normal(jax.random.PRNGKey(seed), (8, 8)) * 5,
            "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (3,))}
    clipped, norm = adamw.clip_by_global_norm(tree, clip)
    new_norm = float(adamw.global_norm(clipped))
    assert new_norm <= clip * 1.001
    if float(norm) <= clip:
        assert abs(new_norm - float(norm)) < 1e-5
