"""Negative tests for ``repro.analysis.checks``: each analyzer family must
provably *flag* a violation, not just pass on the healthy repo.

The ISSUE's acceptance bar: an O(N·V) intermediate, a VMEM overshoot, a
bad input/output alias, an extra device_get, and a misplaced pallas_call
each trip their analyzer. Positive smoke tests (the repo itself passes,
the CLI exits 0) ride along so a regression in either direction is caught.
"""

import dataclasses
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.checks import (CCE_CLASS, CheckError, DENSE_CLASS,
                                   assert_memory_class, check_memory_class,
                                   class_rank, classify_elems, classify_jaxpr)
from repro.analysis.checks import lint, memclass, pallas, syncaudit

N, V, D = 512, 8192, 64   # discriminating: 4*max(N·D, V·D) = 2.1M < N·V 4.2M


def _dense_fn(E, C, x):
    logits = E @ C.T                       # the O(N·V) buffer
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, x[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


def _sds():
    return (jax.ShapeDtypeStruct((N, D), jnp.float32),
            jax.ShapeDtypeStruct((V, D), jnp.float32),
            jax.ShapeDtypeStruct((N,), jnp.int32))


# ---------------------------------------------------------------------------
# memclass
# ---------------------------------------------------------------------------

def test_memclass_flags_dense_intermediate():
    """An explicit N×V logit matrix must be classified O(N·V) and fail."""
    finding = check_memory_class(_dense_fn, *_sds(), n=N, v=V, d=D)
    assert not finding.ok
    assert finding.data["observed"] == DENSE_CLASS
    assert finding.data["largest_elems"] >= N * V
    with pytest.raises(CheckError):
        assert_memory_class(_dense_fn, *_sds(), n=N, v=V, d=D)


def test_memclass_decorator_blocks_dense_call():
    """The decorator form AOT-checks before running: the dense fn never
    executes."""
    wrapped = assert_memory_class(n=N, v=V, d=D)(_dense_fn)
    E = jnp.zeros((N, D), jnp.float32)
    C = jnp.zeros((V, D), jnp.float32)
    x = jnp.zeros((N,), jnp.int32)
    with pytest.raises(CheckError):
        wrapped(E, C, x)


def test_memclass_jaxpr_census_sees_scanned_dense():
    """A dense matmul hidden inside a scan body still shows up in the
    jaxpr census (sub-jaxpr recursion)."""
    def scanned(E, C, x):
        def body(carry, _):
            return carry + _dense_fn(E, C, x), None
        out, _ = jax.lax.scan(body, 0.0, None, length=2)
        return out

    jaxpr = jax.make_jaxpr(scanned)(*_sds())
    assert classify_jaxpr(jaxpr, n=N, v=V, d=D) == DENSE_CLASS


def test_memclass_rejects_vacuous_geometry():
    """budget >= N·V would pass vacuously: the prover refuses to run."""
    assert not memclass.is_discriminating(64, 128, 512)
    with pytest.raises(ValueError, match="not discriminating"):
        check_memory_class("HloModule m", n=64, v=128, d=512)


def test_memclass_rank_and_boundaries():
    assert class_rank(CCE_CLASS) < class_rank("O(N/K·V)") \
        < class_rank(DENSE_CLASS) < class_rank("typo-class")
    budget = memclass.census_budget(N, V, D)
    assert classify_elems(budget, n=N, v=V, d=D) == CCE_CLASS
    assert classify_elems(budget + 1, n=N, v=V, d=D) == "O(N/K·V)"
    assert classify_elems(N * V, n=N, v=V, d=D) == DENSE_CLASS


# ---------------------------------------------------------------------------
# pallas contracts
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fwd_info():
    from repro.kernels import cce_fwd
    infos = pallas.extract_pallas_calls(
        cce_fwd.cce_forward_pallas,
        jax.ShapeDtypeStruct((256, 64), jnp.float32),
        jax.ShapeDtypeStruct((2048, 64), jnp.float32),
        jax.ShapeDtypeStruct((256,), jnp.int32))
    assert infos, "no pallas_call extracted from cce_forward_pallas"
    return infos[0]


def _finding(findings, invariant):
    hits = [f for f in findings if f.invariant == invariant]
    assert hits, f"no {invariant} finding emitted"
    return hits[0]


def test_pallas_flags_vmem_overshoot(fwd_info):
    """The same healthy kernel fails against a budget below its working
    set — the checker measures, it does not rubber-stamp."""
    tiny = fwd_info.structural_vmem() - 1
    bad = _finding(pallas.check_contracts(fwd_info, budget=tiny),
                   "vmem_budget")
    assert not bad.ok
    ok = _finding(pallas.check_contracts(fwd_info), "vmem_budget")
    assert ok.ok


def test_pallas_flags_understated_claim(fwd_info):
    """A claim below the structural working set (beyond slack) fails."""
    understated = fwd_info.structural_vmem() - pallas.CLAIM_SLACK_BYTES - 1
    bad = _finding(
        pallas.check_contracts(fwd_info, claimed_bytes=understated),
        "vmem_claim")
    assert not bad.ok
    with pytest.raises(CheckError):
        from repro.kernels import cce_fwd
        pallas.assert_kernel_contracts(
            cce_fwd.cce_forward_pallas,
            jax.ShapeDtypeStruct((256, 64), jnp.float32),
            jax.ShapeDtypeStruct((2048, 64), jnp.float32),
            jax.ShapeDtypeStruct((256,), jnp.int32),
            claimed_bytes=understated)


def test_pallas_flags_bad_alias(fwd_info):
    """Out-of-range and shape-mismatched aliases are both flagged."""
    oob = dataclasses.replace(fwd_info, aliases=((0, 99),))
    assert not _finding(pallas.check_contracts(oob), "alias_shape").ok

    mismatched = dataclasses.replace(
        fwd_info,
        in_avals=[((256, 64), "float32")],
        out_avals=[((256,), "float32")],
        aliases=((0, 0),))
    bad = _finding(pallas.check_contracts(mismatched), "alias_shape")
    assert not bad.ok and "!=" in bad.detail


def test_pallas_flags_16bit_scratch(fwd_info):
    """A bfloat16 scratch accumulator violates the f32-accum contract."""
    bf16 = dataclasses.replace(
        fwd_info, scratch_avals=[((128, 256), "bfloat16")])
    assert not _finding(pallas.check_contracts(bf16), "accum_f32").ok
    assert _finding(pallas.check_contracts(fwd_info), "accum_f32").ok


def test_pallas_flags_tile_indiscipline(fwd_info):
    """A block that neither divides its array nor lands on the (8,128)
    tile grid is flagged."""
    crooked = dataclasses.replace(fwd_info, in_blocks=[
        pallas.BlockInfo(origin="e_ref", block_shape=(96, 96),
                         array_shape=(256, 2048), dtype="float32")])
    bad = _finding(pallas.check_contracts(crooked), "tile_discipline")
    assert not bad.ok


def test_pallas_entry_points_and_sweep_pass():
    """Positive control: every real kernel entry point and every knob
    combo passes — the negative tests above prove this is not vacuous."""
    findings = pallas.check_kernel_entry_points()
    assert findings and all(f.ok for f in findings), \
        [f.detail for f in findings if not f.ok]
    sweep = pallas.sweep_cce_knobs()
    assert sweep and all(f.ok for f in sweep), \
        [f.detail for f in sweep if not f.ok]


# ---------------------------------------------------------------------------
# sync / retrace audit
# ---------------------------------------------------------------------------

_EXTRA_GET = '''
import jax

class Engine:
    def _sync(self):
        a = jax.device_get(self.status)
        b = jax.device_get(self.extra1)
        c = jax.device_get(self.extra2)
        return a, b, c
'''

_STRAY_GET = '''
import jax

class Engine:
    def step(self):
        return jax.device_get(self.state)   # sync outside _sync
'''

_BUSY_WAIT = '''
import jax

def poll(x):
    x.block_until_ready()
    return x
'''


def test_sync_flags_extra_device_get():
    bad = [f for f in syncaudit.audit_source(_EXTRA_GET)
           if f.invariant == "one_device_get_per_step"]
    assert bad and not bad[0].ok
    assert len(bad[0].data["lines"]) == 3


def test_sync_flags_stray_device_get_and_busy_wait():
    stray = [f for f in syncaudit.audit_source(_STRAY_GET)
             if f.invariant == "device_get_only_in_sync"]
    assert stray and not stray[0].ok
    busy = [f for f in syncaudit.audit_source(_BUSY_WAIT)
            if f.invariant == "no_block_until_ready"]
    assert busy and not busy[0].ok


def test_sync_repo_passes():
    findings = syncaudit.audit_all()
    assert findings and all(f.ok for f in findings), \
        [f.detail for f in findings if not f.ok]


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

def test_lint_flags_misplaced_pallas_call(tmp_path):
    """A pallas_call outside kernels/ fails the location lint."""
    (tmp_path / "kernels").mkdir()
    (tmp_path / "serve").mkdir()
    (tmp_path / "kernels" / "ok.py").write_text(
        "import jax.experimental.pallas as pl\n"
        "launch = pl.pallas_call\n")
    (tmp_path / "serve" / "bad.py").write_text(
        "from jax.experimental import pallas as pl\n"
        "def f(k, x):\n"
        "    return pl.pallas_call(k)(x)\n")
    finding = lint.lint_pallas_location(str(tmp_path))[0]
    assert not finding.ok
    assert any("serve" in m for m in finding.data["misplaced"])
    assert finding.data["kernel_sites"] == 1
    assert lint.find_pallas_calls("y = pl.pallas_call(k)(x)\n") == [1]


def test_lint_repo_passes():
    findings = lint.lint_all()
    assert findings and all(f.ok for f in findings), \
        [f.detail for f in findings if not f.ok]


# ---------------------------------------------------------------------------
# CLI + fixtures
# ---------------------------------------------------------------------------

def test_cli_fast_families_exit_zero(tmp_path):
    """``python -m repro.analysis.checks --only lint --only sync`` exits 0
    and writes a well-formed JSON report."""
    report = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.checks", "--quiet",
         "--only", "lint", "--only", "sync", "--json", str(report)],
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(report.read_text())
    assert payload["ok"] is True
    assert payload["findings"]
    assert {f["family"] for f in payload["findings"]} == {"lint", "sync"}


def test_fixture_check_memory_class(check_memory_class):
    """The pytest fixture resolves to the library helper and still flags
    the dense program."""
    finding = check_memory_class(_dense_fn, *_sds(), n=N, v=V, d=D)
    assert not finding.ok and finding.data["observed"] == DENSE_CLASS
