"""repro.backends: capability matrix, resolution (auto + named), helpful
error text, and uniformity of the lse_pick primitive across backends —
plus cross_entropy's capability-driven dispatch on top of it."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.backends import BackendResolutionError, Requirements
from repro.core import cross_entropy
from repro.kernels.ops import CCEConfig
from repro.kernels.ref import IGNORE_INDEX


def _problem(n=24, d=16, v=160, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    E = jax.random.normal(ks[0], (n, d)) * 0.6
    C = jax.random.normal(ks[1], (v, d)) * 0.5
    x = jax.random.randint(ks[2], (n,), 0, v)
    return E, C, x.at[2].set(IGNORE_INDEX)


# ---------------------------------------------------------------------------
# Registry + capability matrix.
# ---------------------------------------------------------------------------

def test_registry_contains_all_impls():
    assert backends.list_backends() == ["cce", "cce_jax", "chunked",
                                        "dense", "liger"]


def test_capability_matrix_flags():
    caps = dict(backends.capability_matrix())
    # the primitive-capable trio
    for name in ("cce", "cce_jax", "dense"):
        assert caps[name]["custom_cotangents"], name
        assert caps[name]["sum_logits"], name
        assert caps[name]["mesh"], name
        assert not caps[name]["owns_reduction"], name
    # NLL-only baselines
    for name in ("chunked", "liger"):
        assert not caps[name]["custom_cotangents"], name
        assert not caps[name]["mesh"], name
    assert caps["liger"]["owns_reduction"]
    # memory classes distinguish the rows of the paper's Table 1
    assert caps["dense"]["memory_class"] == "O(N·V)"
    assert caps["cce"]["memory_class"] == caps["liger"]["memory_class"]


def test_unknown_backend_error_lists_registered():
    with pytest.raises(BackendResolutionError, match="unknown backend"):
        backends.get("not_a_backend")
    with pytest.raises(BackendResolutionError,
                       match="cce, cce_jax, chunked, dense, liger"):
        backends.resolve("not_a_backend")


# ---------------------------------------------------------------------------
# Resolution: auto picks by platform preference, named impls are validated
# against requirements, and errors enumerate capable backends.
# ---------------------------------------------------------------------------

def test_auto_resolution_prefers_platform():
    be = backends.resolve("auto")
    platform = jax.default_backend()
    assert platform in be.preferred_platforms
    # CPU/GPU -> the scan twin; TPU -> the Pallas kernels
    assert be.name == ("cce" if platform == "tpu" else "cce_jax")


def test_auto_resolution_honors_requirements():
    req = Requirements(custom_cotangents=True, sum_logits=True, mesh=True)
    assert backends.resolve("auto", requirements=req).name in (
        "cce", "cce_jax")


def test_named_resolution_checks_capabilities():
    # a satisfying named backend passes through
    assert backends.resolve(
        "dense", requirements=Requirements(sum_logits=True)).name == "dense"
    # an unsatisfying one raises, and the error names the ones that work
    with pytest.raises(BackendResolutionError) as ei:
        backends.resolve("chunked",
                         requirements=Requirements(custom_cotangents=True))
    msg = str(ei.value)
    assert "chunked" in msg and "Backends that can" in msg
    for capable in ("cce", "cce_jax", "dense"):
        assert capable in msg


def test_owns_reduction_admits_only_mean():
    with pytest.raises(BackendResolutionError, match="owns the reduction"):
        backends.resolve("liger",
                         requirements=Requirements(reduction="none"))
    assert backends.resolve(
        "liger", requirements=Requirements(reduction="mean")).name == "liger"


# ---------------------------------------------------------------------------
# The uniform lse_pick interface: every primitive-capable backend computes
# the same (lse, pick[, sum_logits]).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["cce", "cce_jax"])
def test_lse_pick_uniform_across_backends(name):
    E, C, x = _problem()
    cfg = CCEConfig(block_n=8, block_v=64)
    ref = backends.get("dense").lse_pick(E, C, x, cfg,
                                         with_sum_logits=True)
    out = backends.get(name).lse_pick(E, C, x, cfg, with_sum_logits=True)
    for label, a, b in zip(("lse", "pick", "sum_logits"), out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"{name}/{label}")


def test_nll_only_backends_reject_lse_pick():
    E, C, x = _problem()
    for name in ("chunked", "liger"):
        with pytest.raises(BackendResolutionError):
            backends.get(name).lse_pick(E, C, x, CCEConfig())


# ---------------------------------------------------------------------------
# cross_entropy dispatch on top of the registry.
# ---------------------------------------------------------------------------

def test_cross_entropy_matches_across_all_backends():
    E, C, x = _problem()
    vals = {name: float(cross_entropy(E, C, x, impl=name,
                                      reduction="mean"))
            for name in backends.list_backends()}
    ref = vals["dense"]
    for name, v in vals.items():
        assert abs(v - ref) < 1e-4, (name, v, ref)


def test_cross_entropy_registry_loss_on_nll_only_backend_raises():
    E, C, x = _problem()
    with pytest.raises(BackendResolutionError, match="Backends that can"):
        cross_entropy(E, C, x, loss="z_loss", impl="chunked",
                      reduction="mean")
    with pytest.raises(BackendResolutionError):
        # per-token weights also need the primitive
        cross_entropy(E, C, x, impl="liger", reduction="mean",
                      weights=jnp.ones(x.shape))


def test_cross_entropy_loss_argument_forms():
    from repro.losses import LossConfig, get_loss
    E, C, x = _problem()
    # non-default z_weight, so dropped kwargs cannot masquerade as success
    by_cfg = cross_entropy(E, C, x, loss=LossConfig.create(
        "z_loss", z_weight=0.5), reduction="mean")
    by_obj = cross_entropy(E, C, x, loss=get_loss("z_loss", z_weight=0.5),
                           reduction="mean")
    by_default = cross_entropy(E, C, x, loss="z_loss", reduction="mean")
    assert float(by_cfg) == float(by_obj)
    assert float(by_cfg) != float(by_default)
    with pytest.raises(TypeError, match="registry name"):
        cross_entropy(E, C, x, loss=3.14)


def test_deprecated_shims_still_work():
    E, C, x = _problem()
    with pytest.warns(DeprecationWarning):
        from repro.core import linear_cross_entropy
        old = linear_cross_entropy(E, C, x, reduction="mean")
    new = cross_entropy(E, C, x, reduction="mean")
    assert float(old) == float(new)
