"""Serving subsystem tests: continuous batching golden-equivalence,
mid-flight admission, device-side sampling, CCE-backed scoring, and the
O(1)-host-transfers property of the decode loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer as T
from repro.serve import Engine, SamplingParams, scoring
from repro.serve import sampling as sampling_mod
from repro.serve import scheduler as sched_mod


def _cfg(arch="llama3_2_3b", **over):
    return dataclasses.replace(configs.get_reduced_config(arch),
                               dtype="float32", **over)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [9, 8, 7], [11, 12, 13, 14]]


def _sequential(cfg, params, prompts, max_new, **kw):
    """One-request-at-a-time greedy decode: the golden reference."""
    return [Engine(cfg, params, max_len=64, batch_size=1).generate(
        [p], max_new, **kw)[0] for p in prompts]


# ---------------------------------------------------------------------------
# Golden equivalence: continuous batching == sequential greedy decode.
# ---------------------------------------------------------------------------

def test_continuous_matches_sequential_greedy(model):
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    out = eng.generate(PROMPTS, max_new_tokens=6)   # 4 reqs through 2 slots
    ref = _sequential(cfg, params, PROMPTS, 6)
    assert out == ref


@pytest.mark.parametrize("arch", ["gemma2_2b", "recurrentgemma_9b",
                                  "rwkv6_3b"])
def test_continuous_matches_sequential_other_mixers(arch):
    """Ring-buffer SWA caches and recurrent states are slot-recyclable
    too: per-row timelines must not leak across rows."""
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    prompts = PROMPTS[:3]
    out = Engine(cfg, params, max_len=48,
                 batch_size=2).generate(prompts, 5)
    ref = [Engine(cfg, params, max_len=48, batch_size=1).generate(
        [p], 5)[0] for p in prompts]
    assert out == ref


# ---------------------------------------------------------------------------
# Chunked prefill: token-identical to one-token teacher forcing.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma2_2b",
                                  "recurrentgemma_9b", "rwkv6_3b",
                                  "olmoe_1b_7b"])
def test_chunked_prefill_matches_one_token(arch):
    """prefill_chunk > 1 (ragged final chunks included) must replay the
    exact token streams of one-token teacher forcing for every mixer
    family: dense attention, ring-buffer SWA, RG-LRU, RWKV-6, and MoE
    (whose serve path must be drop-free)."""
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    ref = [Engine(cfg, params, max_len=48, batch_size=1).generate(
        [p], 5)[0] for p in PROMPTS]
    for chunk in (3, 8):    # 3: multi-chunk + ragged tail; 8: one bite
        out = Engine(cfg, params, max_len=48, batch_size=2,
                     prefill_chunk=chunk).generate(PROMPTS, 5)
        assert out == ref, f"chunk={chunk}"


def test_chunked_prefill_encdec_matches_one_token():
    """Cross-attention rows prefill in chunks too (every chunk position
    attends the full encoder output)."""
    cfg = _cfg("seamless_m4t_medium")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    enc = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model)) * 0.5
    prompts = [[1, 2, 3, 4, 5], [3, 4]]
    ref = Engine(cfg, params, max_len=32, batch_size=2,
                 enc_out=enc).generate(prompts, 3)
    out = Engine(cfg, params, max_len=32, batch_size=2, prefill_chunk=4,
                 enc_out=enc).generate(prompts, 3)
    assert out == ref


def test_chunked_prefill_sampled_streams_identical(model):
    """Each row's PRNG stream advances per consumed token, not per engine
    step — so chunked prefill replays SAMPLED tokens too."""
    cfg, params = model
    sp = SamplingParams(temperature=0.7, top_k=13, top_p=0.9, seed=5)
    a = Engine(cfg, params, max_len=64, batch_size=2).generate(
        PROMPTS, 6, sampling=sp)
    b = Engine(cfg, params, max_len=64, batch_size=2,
               prefill_chunk=4).generate(PROMPTS, 6, sampling=sp)
    assert a == b


def test_chunked_prefill_mid_flight_admission(model):
    """A request admitted while other rows are decoding prefills in
    chunks without disturbing them — everyone still produces their
    sequential-reference tokens."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4)
    r0 = eng.submit(PROMPTS[0], max_new_tokens=6)
    comps = {}
    for c in eng.step():            # r0 starts prefilling/decoding alone
        comps[c.rid] = c
    r3 = eng.submit(PROMPTS[3], max_new_tokens=6)   # joins mid-flight
    comps.update(eng.run())
    ref = _sequential(cfg, params, [PROMPTS[0], PROMPTS[3]], 6)
    assert [comps[r0].tokens, comps[r3].tokens] == ref


def test_chunked_prefill_one_host_transfer_per_step(model, monkeypatch):
    """Piggyback prefill must not add host syncs: still exactly one
    device_get per step (2 when something finishes)."""
    cfg, params = model
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    eng = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4)
    for p in PROMPTS[:2]:
        eng.submit(p, max_new_tokens=4)
    calls.clear()
    while eng.has_work():
        before = len(calls)
        done = eng.step()
        assert len(calls) - before == (2 if done else 1)


def test_mid_flight_admission(model):
    """A request enqueued after decoding has started completes with
    exactly the tokens it would produce alone."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    r0 = eng.submit(PROMPTS[0], max_new_tokens=6)
    r1 = eng.submit(PROMPTS[1], max_new_tokens=6)
    comps = {}
    for c in eng.step():                    # step 0: only r0/r1 on board
        comps[c.rid] = c
    r2 = eng.submit(PROMPTS[2], max_new_tokens=6)   # joins mid-flight
    comps.update(eng.run())
    ref = _sequential(cfg, params, PROMPTS[:3], 6)
    assert [comps[r].tokens for r in (r0, r1, r2)] == ref


def test_slot_reuse_is_clean(model):
    """Back-to-back generations through the same engine (slots recycled
    many times) keep producing the sequential-reference tokens."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    ref = _sequential(cfg, params, PROMPTS, 4)
    for _ in range(2):
        assert eng.generate(PROMPTS, max_new_tokens=4) == ref


def test_eos_stops_row(model):
    cfg, params = model
    base = Engine(cfg, params, max_len=64,
                  batch_size=1).generate([PROMPTS[0]], 8)[0]
    # first position whose token did not appear earlier in the output —
    # using it as EOS must truncate exactly there
    k = next(i for i in range(1, len(base)) if base[i] not in base[:i])
    eng = Engine(cfg, params, max_len=64, batch_size=1)
    rid = eng.submit(PROMPTS[0], max_new_tokens=8, eos_token=base[k])
    comp = eng.run()[rid]
    assert comp.tokens == base[:k + 1]      # EOS included, then stop
    assert comp.finish_reason == "eos"


# ---------------------------------------------------------------------------
# Sampling.
# ---------------------------------------------------------------------------

def test_sampling_deterministic_and_batch_invariant(model):
    """Seeded sampling replays identically, and a request's tokens do not
    depend on what else shares the batch (per-row PRNG streams)."""
    cfg, params = model
    sp = SamplingParams(temperature=0.7, top_k=13, top_p=0.9, seed=5)
    a = Engine(cfg, params, max_len=64, batch_size=2).generate(
        PROMPTS[:2], 6, sampling=sp)
    b = Engine(cfg, params, max_len=64, batch_size=2).generate(
        PROMPTS[:2], 6, sampling=sp)
    assert a == b
    alone = Engine(cfg, params, max_len=64, batch_size=1).generate(
        [PROMPTS[0]], 6, sampling=sp)[0]
    assert a[0] == alone


def test_sample_tokens_temperature_zero_is_greedy():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 37))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4))
    out = sampling_mod.sample_tokens(
        logits, keys, jnp.zeros((4,)), jnp.zeros((4,), jnp.int32),
        jnp.ones((4,)))
    np.testing.assert_array_equal(out, jnp.argmax(logits, -1))


def test_sample_tokens_top_k_one_is_greedy_per_row():
    """top_k=1 forces the argmax even at high temperature — and per-row
    params mix freely in one call."""
    logits = jax.random.normal(jax.random.PRNGKey(1), (3, 29))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3))
    out = sampling_mod.sample_tokens(
        logits, keys, jnp.asarray([5.0, 0.0, 5.0]),
        jnp.asarray([1, 0, 1], jnp.int32), jnp.ones((3,)))
    np.testing.assert_array_equal(out, jnp.argmax(logits, -1))


def test_sample_tokens_top_p_tiny_is_greedy():
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 53))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4))
    out = sampling_mod.sample_tokens(
        logits, keys, jnp.full((4,), 3.0), jnp.zeros((4,), jnp.int32),
        jnp.full((4,), 1e-6))
    np.testing.assert_array_equal(out, jnp.argmax(logits, -1))


def test_sampling_params_validate():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0).validate(100)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0).validate(100)
    with pytest.raises(ValueError):
        SamplingParams(top_k=101).validate(100)


# ---------------------------------------------------------------------------
# O(1) host transfers per engine step.
# ---------------------------------------------------------------------------

def test_one_host_transfer_per_step(model, monkeypatch):
    """The decode loop performs exactly one device_get per step when no
    request finishes (and 2 on finishing steps), independent of batch
    size — never a per-row int(...) sync."""
    cfg, params = model
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    for bs in (2, 4):
        eng = Engine(cfg, params, max_len=64, batch_size=bs)
        for p in PROMPTS[:bs]:
            eng.submit(p, max_new_tokens=4)
        calls.clear()
        n_steps = 0
        while eng.has_work():
            before = len(calls)
            done = eng.step()
            n_steps += 1
            assert len(calls) - before == (2 if done else 1), \
                f"batch={bs}: host transfers grew with the step"
        assert n_steps > 1


# ---------------------------------------------------------------------------
# Scoring.
# ---------------------------------------------------------------------------

def test_scoring_matches_dense_logprobs(model):
    """CCE-backed score == dense log_softmax(E @ C.T) gather."""
    cfg, params = model
    prompt = [1, 2, 3]
    comps = [[4, 5], [6], [7, 8, 9]]
    got = scoring.score(params, cfg, prompt, comps)

    toks, _ = scoring.build_scoring_batch(prompt, comps)
    hidden, _, _ = T.lm_hidden(params, cfg, {"tokens": jnp.asarray(toks)})
    C = T.classifier_matrix(params, cfg)
    ls = jax.nn.log_softmax(
        hidden.astype(jnp.float32) @ C.astype(jnp.float32).T, axis=-1)
    want = [sum(float(ls[i, len(prompt) - 1 + j, t])
                for j, t in enumerate(c)) for i, c in enumerate(comps)]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_scoring_normalize_tokens(model):
    cfg, params = model
    prompt = [1, 2, 3]
    comps = [[4, 5], [6, 7, 8, 9]]
    raw = scoring.score(params, cfg, prompt, comps, normalize="sum")
    norm = scoring.score(params, cfg, prompt, comps, normalize="tokens")
    np.testing.assert_allclose(norm, [raw[0] / 2, raw[1] / 4], rtol=1e-5)


def test_token_logprobs_sum_to_score(model):
    cfg, params = model
    prompt = [1, 2, 3]
    comps = [[4, 5, 6], [7]]
    per_tok = scoring.token_logprobs(params, cfg, prompt, comps)
    s = scoring.score(params, cfg, prompt, comps)
    assert [len(t) for t in per_tok] == [3, 1]
    np.testing.assert_allclose([sum(t) for t in per_tok], s,
                               rtol=1e-4, atol=1e-4)


def test_scoring_impl_agreement(model):
    cfg, params = model
    prompt = [5, 6]
    comps = [[1, 2], [3]]
    a = scoring.score(params, cfg, prompt, comps, impl="cce_jax")
    b = scoring.score(params, cfg, prompt, comps, impl="dense")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_scoring_sharded_matches_local(model):
    """score(mesh=...) runs the scorer under the vocab-parallel combine
    and must agree with the local path — and must NOT be conflated with
    the meshless jit by the scorer cache (the cache key includes
    mesh/vocab_axis/token_axes, so interleaved calls stay correct)."""
    from jax.sharding import Mesh

    cfg, params = model
    prompt = [1, 2, 3]
    comps = [[4, 5], [6], [7, 8, 9]]
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    local = scoring.score(params, cfg, prompt, comps, impl="cce_jax")
    shard = scoring.score(params, cfg, prompt, comps, impl="cce_jax",
                          mesh=mesh)
    np.testing.assert_allclose(shard, local, rtol=1e-5, atol=1e-5)
    again = scoring.score(params, cfg, prompt, comps, impl="cce_jax")
    np.testing.assert_allclose(again, local, rtol=0)
    per_tok = scoring.token_logprobs(params, cfg, prompt, comps, mesh=mesh)
    np.testing.assert_allclose([sum(t) for t in per_tok], local,
                               rtol=1e-4, atol=1e-4)


def test_scoring_hlo_has_no_batched_vocab_buffer(assert_memory_class):
    """The jitted scorer's optimized HLO must contain no (N, V)-class
    array: vocab is enlarged so a kernel tile cannot coincide with N×V
    (classification via repro.analysis.checks, same convention as
    benchmarks/loss_zoo_memory)."""
    from repro.analysis.checks import DENSE_CLASS, classify_hlo

    cfg = _cfg(vocab_size=32768)
    b, s = 8, 64
    n, v, d = b * s, cfg.padded_vocab_size, cfg.d_model
    params_sds = jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
    fn = scoring.score_fn(cfg, impl="cce_jax")
    assert_memory_class(jax.jit(fn), params_sds, toks, toks,
                        n=n, v=v, d=d, what="scoring(cce_jax)")
    # control: the dense scorer at the same size does materialize (N, V)
    dense = scoring.score_fn(cfg, impl="dense")
    text = jax.jit(dense).lower(params_sds, toks, toks).compile().as_text()
    assert classify_hlo(text, n=n, v=v, d=d) == DENSE_CLASS


def test_build_scoring_batch_shapes_and_labels():
    toks, labels = scoring.build_scoring_batch([1, 2], [[3, 4], [5]])
    np.testing.assert_array_equal(toks, [[1, 2, 3, 4], [1, 2, 5, 0]])
    ii = -100
    np.testing.assert_array_equal(labels, [[ii, 3, 4, ii],
                                           [ii, 5, ii, ii]])
    with pytest.raises(ValueError):
        scoring.build_scoring_batch([], [[1]])
    with pytest.raises(ValueError):
        scoring.build_scoring_batch([1], [[]])


# ---------------------------------------------------------------------------
# Engine validation / bookkeeping.
# ---------------------------------------------------------------------------

def test_submit_validation(model):
    cfg, params = model
    eng = Engine(cfg, params, max_len=32, batch_size=1)
    with pytest.raises(ValueError):
        eng.submit(list(range(30)), max_new_tokens=10)  # needs 39 positions
    with pytest.raises(ValueError):
        eng.submit([1], max_new_tokens=0)
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=32, batch_size=1, prefill_chunk=0)


def test_submit_exactly_fitting_request_completes(model):
    """The last sampled token is never fed back, so prompt + max_new can
    exceed max_len by one: such a request must be accepted and finish
    with "length" — not be refused, and not die as "cache_full"."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=32, batch_size=1)
    rid = eng.submit(list(range(1, 31)), max_new_tokens=3)  # 30+3-1 == 32
    comp = eng.run()[rid]
    assert comp.finish_reason == "length"
    assert len(comp.tokens) == 3
    with pytest.raises(ValueError):     # one past the exact fit
        eng.submit(list(range(1, 31)), max_new_tokens=4)


def test_run_max_steps_clamps_final_substeps(model):
    """run(max_steps=4, substeps=8) must execute exactly 4 decode steps,
    not one unconditional 8-substep batch."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=1)
    eng.submit(PROMPTS[0], max_new_tokens=16)
    eng.run(substeps=8, max_steps=4)
    assert eng.step_count == 4


def test_ttft_attributed_to_first_token_step(model):
    """Under substeps > 1, TTFT comes from the device-side step index of
    each row's first generated token — rows finishing their prompt at
    different steps inside ONE sync window get distinct, ordered TTFTs
    (the old host-sync stamping gave them all the same time)."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    r_short = eng.submit([1], max_new_tokens=4)
    r_long = eng.submit(list(range(1, 13)), max_new_tokens=4)
    comps = eng.run(substeps=32)        # whole workload, single sync
    ts, tl = comps[r_short].first_token_time, comps[r_long].first_token_time
    assert ts is not None and tl is not None
    assert ts < tl                      # step 1 vs step 12, same window
    assert comps[r_long].finish_time >= tl


def test_admission_is_single_pass_fifo(model):
    """Admission fills free slots strictly in submission order (earliest
    request -> lowest free slot); the overflow stays queued in order."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    sub = lambda: eng.submit([1, 2], max_new_tokens=4)
    r0, r1, r2, r3 = sub(), sub(), sub(), sub()
    sch = eng.scheduler
    eng.step()
    assert [sch.slots[0].rid, sch.slots[1].rid] == [r0, r1]
    assert [r.rid for r in sch.queue] == [r2, r3]


def test_admission_pinned_request_does_not_block_later(model):
    """A request pinned to a busy slot waits without blocking a later
    unpinned request, and keeps its queue position."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    sch = eng.scheduler
    ra = eng.submit([1, 2], max_new_tokens=4)
    eng.step()
    assert sch.slots[0].rid == ra and sch.slots[1] is None
    rp = sch.submit(sched_mod.Request(prompt=[3], max_new_tokens=2,
                                      slot=0))    # pinned to busy slot 0
    ru = eng.submit([4, 5], max_new_tokens=2)
    eng.state, eng.cache, rows = sch.admit(eng.state, eng.cache)
    assert rows == [1] and sch.slots[1].rid == ru
    assert [r.rid for r in sch.queue] == [rp]     # still first in line


def test_enc_out_blocks_slot_recycling(model):
    """With enc_out set, rows map to encoder rows by slot: submitting more
    than batch_size requests must be refused, not silently mispaired."""
    cfg, params = model
    enc = jnp.zeros((2, 4, cfg.d_model), jnp.float32)
    eng = Engine(cfg, params, max_len=64, batch_size=2, enc_out=enc)
    eng.submit([1, 2], max_new_tokens=2)
    eng.submit([3, 4], max_new_tokens=2)
    with pytest.raises(ValueError):
        eng.submit([5, 6], max_new_tokens=2)


def test_enc_out_pins_requests_to_their_encoder_row():
    """A request submitted after an earlier one retired must still meet
    ITS OWN encoder row, not the freed slot's."""
    cfg = _cfg("seamless_m4t_medium")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    enc = jax.random.normal(jax.random.PRNGKey(1),
                            (2, 4, cfg.d_model)) * 0.5
    # reference: request B decoded alone against encoder row 1
    ref = Engine(cfg, params, max_len=32, batch_size=1,
                 enc_out=enc[1:2]).generate([[3, 4]], 3)[0]
    # A occupies slot 0, finishes, THEN B is submitted: without slot
    # pinning B would recycle slot 0 and read A's encoder row 0
    eng = Engine(cfg, params, max_len=32, batch_size=2, enc_out=enc)
    ra = eng.submit([1, 2], max_new_tokens=2)
    comps = eng.run()
    assert ra in comps
    rb = eng.submit([3, 4], max_new_tokens=3)
    assert eng.run()[rb].tokens == ref


def test_completion_metadata(model):
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2)
    rid = eng.submit(PROMPTS[0], max_new_tokens=3)
    comp = eng.run()[rid]
    assert comp.rid == rid
    assert comp.prompt == PROMPTS[0]
    assert comp.finish_reason == "length"
    assert len(comp.tokens) == 3
    assert comp.first_token_time is not None
    assert comp.finish_time >= comp.submit_time


# ---------------------------------------------------------------------------
# Satellite regression: Trainer forwards dispatch arguments.
# ---------------------------------------------------------------------------

def test_trainer_forwards_dispatch_arguments():
    """Trainer(loss_impl=...) must reach the backend registry — it used to
    be silently dropped, so an incapable backend 'worked'."""
    from repro.backends import BackendResolutionError
    from repro.configs.base import TrainConfig
    from repro.train import Trainer

    cfg = _cfg("llama3_2_3b")
    tcfg = TrainConfig(total_steps=1, warmup_steps=1, loss="z_loss",
                       loss_kwargs=(("z_weight", 1e-4),))
    # chunked cannot serve a registry loss (no custom cotangents): with the
    # argument actually forwarded this must fail at trace time
    tr = Trainer(cfg, tcfg, seq_len=16, global_batch=2,
                 loss_impl="chunked", jit=False)
    with pytest.raises(BackendResolutionError):
        tr.run(num_steps=1, log_fn=None)
    # and a capable backend trains normally through the same passthrough
    hist = Trainer(cfg, tcfg, seq_len=16, global_batch=2,
                   loss_impl="cce_jax").run(num_steps=1, log_every=1,
                                            log_fn=None)
    assert np.isfinite(hist[-1]["loss"])


def test_trainer_forwards_mesh():
    """mesh/vocab_axis/token_axes passthrough: the vocab-parallel head
    runs under a 1x1 mesh and matches the local loss."""
    from jax.sharding import Mesh

    from repro.configs.base import TrainConfig
    from repro.train import Trainer

    cfg = _cfg("llama3_2_3b")
    tcfg = TrainConfig(total_steps=2, warmup_steps=1, seed=3)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    a = Trainer(cfg, tcfg, seq_len=16, global_batch=2, mesh=mesh,
                loss_impl="cce_jax").run(num_steps=2, log_every=1,
                                         log_fn=None)
    b = Trainer(cfg, tcfg, seq_len=16, global_batch=2,
                loss_impl="cce_jax").run(num_steps=2, log_every=1,
                                         log_fn=None)
    np.testing.assert_allclose([h["loss"] for h in a],
                               [h["loss"] for h in b], rtol=1e-5)


def test_trainer_forwards_cce_cfg():
    """cce_cfg passthrough: a CCEConfig with sort_vocab still trains and
    matches the default config's loss (sorting is numerics-neutral)."""
    from repro.configs.base import TrainConfig
    from repro.kernels.ops import CCEConfig
    from repro.train import Trainer

    cfg = _cfg("llama3_2_3b")
    tcfg = TrainConfig(total_steps=1, warmup_steps=1, seed=4)
    a = Trainer(cfg, tcfg, seq_len=16, global_batch=2,
                cce_cfg=CCEConfig(sort_vocab=True),
                loss_impl="cce_jax").run(num_steps=1, log_every=1,
                                         log_fn=None)
    b = Trainer(cfg, tcfg, seq_len=16, global_batch=2,
                loss_impl="cce_jax").run(num_steps=1, log_every=1,
                                         log_fn=None)
    np.testing.assert_allclose(a[-1]["loss"], b[-1]["loss"], rtol=1e-5)


def test_cce_cli_flags_validate_against_dataclass():
    import argparse

    from repro.launch.cce_flags import add_cce_args, cce_config_from_args

    ap = argparse.ArgumentParser()
    add_cce_args(ap)
    args = ap.parse_args(["--cce-sort-vocab", "--cce-accum", "bf16_kahan",
                          "--cce-filter-mode-c", "full"])
    c = cce_config_from_args(args)
    assert c.sort_vocab and c.accum == "bf16_kahan"
    assert c.filter_mode_c == "full" and c.filter_mode_e == "filtered"
    assert c.bwd == "fused" and c.filter_stats == "fwd_bitmap"  # defaults
    args = ap.parse_args(["--cce-bwd", "two_pass",
                          "--cce-filter-stats", "recompute"])
    c = cce_config_from_args(args)
    assert c.bwd == "two_pass" and c.filter_stats == "recompute"
    assert cce_config_from_args(ap.parse_args([])) is None
    with pytest.raises(SystemExit):
        ap.parse_args(["--cce-accum", "f64"])   # not a CCEConfig choice
    with pytest.raises(SystemExit):
        ap.parse_args(["--cce-bwd", "atomic"])  # not a CCEConfig choice


# ---------------------------------------------------------------------------
# Observability: metrics must ride the existing per-step sync for free.
# ---------------------------------------------------------------------------

def test_one_host_transfer_per_step_with_metrics(model, monkeypatch,
                                                 tmp_path):
    """Enabling the full observability stack (registry + JSONL tracer)
    must not add host transfers: still exactly one device_get per step
    (2 on finishing steps) — the zero-sync invariant of DESIGN.md §8."""
    from repro.obs import JsonlSink, Registry, Tracer, read_jsonl

    cfg, params = model
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    reg = Registry()
    sink = JsonlSink(tmp_path / "serve.jsonl")
    eng = Engine(cfg, params, max_len=64, batch_size=2,
                 metrics=reg, tracer=Tracer(sink))
    for p in PROMPTS[:2]:
        eng.submit(p, max_new_tokens=4)
    calls.clear()
    comps = {}
    while eng.has_work():
        before = len(calls)
        done = eng.step()
        comps.update({c.rid: c for c in done})
        assert len(calls) - before == (2 if done else 1), \
            "metrics added host transfers to the decode loop"
    sink.close()

    # ...and the telemetry recorded through that one sync is right
    assert reg.value("serve_generated_tokens_total") == 8       # 2 x 4
    assert reg.total("serve_requests_finished_total") == 2
    assert reg.value("serve_requests_finished_total",
                     {"reason": "length"}) == 2
    assert reg.histogram("serve_ttft_seconds").count == 2
    assert reg.value("serve_slots_occupied") == 0               # all done
    assert reg.value("serve_slots_total") == 2
    assert reg.value("serve_prefill_tokens_total") == \
        len(PROMPTS[0]) + len(PROMPTS[1])
    spans = [r for r in read_jsonl(tmp_path / "serve.jsonl")
             if r["type"] == "span" and r["name"] == "request"]
    assert sorted(s["rid"] for s in spans) == sorted(comps)
    for s in spans:
        assert s["n_tokens"] == 4 and s["finish_reason"] == "length"
        assert s["dur"] >= 0 and s["ttft_s"] >= 0


def test_metrics_do_not_recompile_engine_step(model):
    """The disabled->enabled transition must not touch the jitted step:
    metrics are host-side only, so the module-level _engine_step cache
    gains no entries when an instrumented engine reuses a warm config."""
    from repro.obs import Registry
    from repro.serve import engine as engine_mod

    cfg, params = model
    Engine(cfg, params, max_len=64, batch_size=2).generate(
        PROMPTS[:2], 2)                                   # warm the cache
    before = engine_mod._engine_step._cache_size()
    eng = Engine(cfg, params, max_len=64, batch_size=2,
                 metrics=Registry())
    out = eng.generate(PROMPTS[:2], 2)
    assert engine_mod._engine_step._cache_size() == before, \
        "enabling metrics recompiled the engine step"
    assert out == Engine(cfg, params, max_len=64,
                         batch_size=2).generate(PROMPTS[:2], 2)


# ---------------------------------------------------------------------------
# Paged KV pool: Engine(kv_page_size=...) — token identity, prefix reuse,
# page-budget backpressure, and the zero-extra-sync invariants.
# ---------------------------------------------------------------------------

# ten tokens = 2 full pages at kv_page_size=4: enough shared prefix for
# page-aligned reuse with a teacher-forced tail left over
SHARED_PREFIX = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]
PREFIX_PROMPTS = [SHARED_PREFIX + tail
                  for tail in ([7], [8, 9], [10, 11, 12], [13])]


@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma2_2b",
                                  "recurrentgemma_9b", "rwkv6_3b",
                                  "olmoe_1b_7b"])
def test_paged_matches_dense_all_mixers(arch):
    """The paged KV layout must replay dense token streams exactly for
    every mixer family — dense attention reads/writes through the page
    table, SWA rings and recurrent states stay slot-dense — including
    mid-flight admission (4 requests through 2 slots) and chunked
    prefill."""
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    ref = Engine(cfg, params, max_len=48, batch_size=2,
                 prefill_chunk=3).generate(PROMPTS, 5)
    out = Engine(cfg, params, max_len=48, batch_size=2, prefill_chunk=3,
                 kv_page_size=4).generate(PROMPTS, 5)
    assert out == ref


def test_paged_sampled_streams_identical(model):
    cfg, params = model
    sp = SamplingParams(temperature=0.7, top_k=13, top_p=0.9, seed=5)
    dense = Engine(cfg, params, max_len=64, batch_size=2,
                   prefill_chunk=4).generate(PROMPTS, 6, sampling=sp)
    paged = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4,
                   kv_page_size=4).generate(PROMPTS, 6, sampling=sp)
    assert paged == dense


def test_paged_prefix_reuse_token_identity(model):
    """Shared-prefix requests map resident prefix pages copy-free — and
    still produce exactly the dense engine's tokens. The engine must
    record real hits (the first wave publishes, the second reuses)."""
    cfg, params = model
    ref = _sequential(cfg, params, PREFIX_PROMPTS, 5)
    eng = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4,
                 kv_page_size=4)
    out = eng.generate(PREFIX_PROMPTS, 5)
    assert out == ref
    st = eng.pool.stats()
    assert st["hit_requests_total"] > 0
    assert st["prefix_hit_rate"] > 0
    assert eng.pool.reused_pages_total > 0
    eng.pool.check_invariants()
    assert st["in_use_pages"] == 0          # everything retired


def test_paged_prefix_reuse_sampled_identity(model):
    """A request admitted onto reused pages skips prefill steps — its
    PRNG stream is pre-advanced past the skipped span, so SAMPLED tokens
    are identical to the dense engine's too."""
    cfg, params = model
    sp = SamplingParams(temperature=0.8, top_k=20, seed=3)
    dense = Engine(cfg, params, max_len=64, batch_size=2,
                   prefill_chunk=4).generate(PREFIX_PROMPTS, 6, sampling=sp)
    eng = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4,
                 kv_page_size=4)
    assert eng.generate(PREFIX_PROMPTS, 6, sampling=sp) == dense
    assert eng.pool.reused_pages_total > 0


def test_paged_encdec_matches_dense():
    """Self-attention rows page; cross-attention stays slot-dense (it is
    encoder-length, never grows) — streams must match the dense engine."""
    cfg = _cfg("seamless_m4t_medium")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    enc = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model)) * 0.5
    prompts = [[1, 2, 3, 4, 5], [3, 4]]
    ref = Engine(cfg, params, max_len=32, batch_size=2,
                 enc_out=enc).generate(prompts, 3)
    out = Engine(cfg, params, max_len=32, batch_size=2, kv_page_size=4,
                 enc_out=enc).generate(prompts, 3)
    assert out == ref


def test_paged_one_host_transfer_per_step_with_metrics(model, monkeypatch):
    """Paging + full metrics: the pool is host-side bookkeeping, so still
    exactly one device_get per step (2 on finishing steps) — and the pool
    gauges/counters recorded through it are right."""
    from repro.obs import Registry

    cfg, params = model
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    reg = Registry()
    eng = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4,
                 kv_page_size=4, metrics=reg)
    for p in PREFIX_PROMPTS:
        eng.submit(p, max_new_tokens=4)
    calls.clear()
    while eng.has_work():
        before = len(calls)
        done = eng.step()
        assert len(calls) - before == (2 if done else 1), \
            "the KV pool added host transfers to the decode loop"
    st = eng.pool.stats()
    assert reg.value("serve_kvpool_pages_total") == eng.pool.num_pages
    assert reg.value("serve_kvpool_free_pages") == st["free_pages"]
    assert reg.value("serve_kvpool_cached_pages") == st["cached_pages"]
    assert reg.value("serve_kvpool_peak_pages") == st["peak_pages"] > 0
    assert reg.value("serve_prefix_pages_reused_total") == \
        eng.pool.reused_pages_total > 0
    assert reg.value("serve_prefix_hit_requests_total") == \
        eng.pool.hit_requests_total > 0
    assert reg.value("serve_prefix_pages_published_total") == \
        eng.pool.published_pages_total > 0


def test_paged_metrics_do_not_recompile_engine_step(model):
    """Same no-recompile discipline on the paged path: enabling metrics on
    a warm paged shape must not grow the module-level step cache."""
    from repro.obs import Registry
    from repro.serve import engine as engine_mod

    cfg, params = model
    ref = Engine(cfg, params, max_len=64, batch_size=2,
                 kv_page_size=4).generate(PROMPTS[:2], 2)   # warm the cache
    before = engine_mod._engine_step._cache_size()
    out = Engine(cfg, params, max_len=64, batch_size=2, kv_page_size=4,
                 metrics=Registry()).generate(PROMPTS[:2], 2)
    assert engine_mod._engine_step._cache_size() == before, \
        "enabling metrics recompiled the paged engine step"
    assert out == ref


def test_paged_page_budget_backpressure(model):
    """A pool sized for one request at a time: the second request must
    wait (admission backpressure, FIFO preserved), admit after the first
    retires — evicting its cached prefix pages if needed — and still
    produce its sequential-reference tokens."""
    cfg, params = model
    # each request: 7-token prompt + 5 new -> 11 positions -> 3 pages
    eng = Engine(cfg, params, max_len=32, batch_size=2, kv_page_size=4,
                 kv_pages=3)
    ra = eng.submit(PROMPTS[0], max_new_tokens=5)               # 3 pages
    rb = eng.submit([21, 22, 23, 24, 25, 26, 27], max_new_tokens=5)
    eng.step()
    sch = eng.scheduler
    assert sch.slots[0].rid == ra
    assert sch.slots[1] is None, "page-starved request was admitted"
    assert [r.rid for r in sch.queue] == [rb]                   # FIFO kept
    comps = eng.run()
    assert set(comps) == {ra, rb}
    ref = _sequential(cfg, params,
                      [PROMPTS[0], [21, 22, 23, 24, 25, 26, 27]], 5)
    assert [comps[ra].tokens, comps[rb].tokens] == ref
    eng.pool.check_invariants()
    assert eng.pool.stats()["in_use_pages"] == 0


def test_paged_pinned_wait_does_not_starve_fifo(model):
    """A slot-pinned request waiting on its BUSY slot steps aside without
    tripping the page-budget backpressure break — later unpinned requests
    that fit must still admit (the page gate only fires for requests whose
    slot is actually available)."""
    cfg, params = model
    # pool of 5: ra takes 4 pages; rp (pinned to ra's slot) would need 5
    eng = Engine(cfg, params, max_len=32, batch_size=2, kv_page_size=4,
                 kv_pages=5)
    sch = eng.scheduler
    ra = eng.submit(PROMPTS[0], max_new_tokens=10)      # 16 pos -> 4 pages
    eng.step()
    assert sch.slots[0].rid == ra and sch.slots[1] is None
    rp = sch.submit(sched_mod.Request(prompt=[3], max_new_tokens=20,
                                      slot=0))          # busy slot, 5 pages
    ru = eng.submit([4, 5], max_new_tokens=2)           # 3 pos -> 1 page
    eng.state, eng.cache, rows = sch.admit(eng.state, eng.cache)
    assert rows == [1] and sch.slots[1].rid == ru, \
        "pinned request waiting on a busy slot starved FIFO admission"
    assert [r.rid for r in sch.queue] == [rp]           # still first in line
    for i in rows:      # mirror Engine.step's admission bookkeeping
        eng._prefill_left[i] = len(sch.slots[i].prompt) - \
            sch.slots[i].reused_tokens
    comps = eng.run()
    assert set(comps) == {ra, rp, ru}                   # rp eventually ran
    eng.pool.check_invariants()


def test_paged_submit_and_flag_validation(model):
    cfg, params = model
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=32, batch_size=1, kv_pages=4)
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=32, batch_size=1, kv_page_size=0)
    eng = Engine(cfg, params, max_len=32, batch_size=1, kv_page_size=4,
                 kv_pages=2)
    with pytest.raises(ValueError):     # needs 3 pages > pool's 2: never
        eng.submit(PROMPTS[0], max_new_tokens=5)        # admittable
    rid = eng.submit([1, 2, 3], max_new_tokens=4)       # 6 pos: fits
    assert len(eng.run()[rid].tokens) == 4


def test_reset_cache_rows_preserves_pool_pages(model):
    """Recycling a slot must only unmap its page-table row — the shared
    page pools hold other rows' (and cached prefixes') K/V and are never
    zeroed. SWA rings/recurrent states stay slot-dense and DO reset."""
    cfg, _ = model
    cache = T.init_cache(cfg, 2, 32, kv_page_size=4)
    poked = jax.tree.map(
        lambda a: jnp.full_like(a, 7) if a.dtype != jnp.int32 else a, cache)
    poked["pt"] = jnp.asarray([[0, 1, -1, -1, -1, -1, -1, -1],
                               [2, 3, 4, -1, -1, -1, -1, -1]], jnp.int32)
    out = T.reset_cache_rows(poked, jnp.asarray([True, False]))
    entries = list(out["groups"]) + list(out.get("tail", []))
    k_pages = [e["k_pages"] for e in entries
               if isinstance(e, dict) and "k_pages" in e]
    assert k_pages, "paged cache lost its page pools"
    for kp in k_pages:
        assert bool((kp == 7).all()), "reset zeroed shared pool pages"
    np.testing.assert_array_equal(
        out["pt"], [[-1] * 8, [2, 3, 4, -1, -1, -1, -1, -1]])


# ---------------------------------------------------------------------------
# Fused (logit-free) decode: Engine(decode_kernel="fused") routes the step
# through kernels.decode_sample — greedy must be token-identical to the
# dense oracle everywhere, and no (B, V) buffer may exist in the fused jit.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma2_2b",
                                  "recurrentgemma_9b", "rwkv6_3b",
                                  "olmoe_1b_7b"])
def test_fused_greedy_matches_dense_all_mixers(arch):
    """Golden token identity: the fused projection->sample path replays
    the dense engine's greedy streams exactly for every mixer family,
    through slot recycling (4 requests / 2 slots) and chunked prefill."""
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    ref = Engine(cfg, params, max_len=48, batch_size=2,
                 prefill_chunk=3).generate(PROMPTS, 5)
    out = Engine(cfg, params, max_len=48, batch_size=2, prefill_chunk=3,
                 decode_kernel="fused").generate(PROMPTS, 5)
    assert out == ref


def test_fused_mid_flight_admission(model):
    """A request admitted while fused rows are decoding still produces
    its sequential-reference tokens."""
    cfg, params = model
    eng = Engine(cfg, params, max_len=64, batch_size=2,
                 decode_kernel="fused")
    r0 = eng.submit(PROMPTS[0], max_new_tokens=6)
    comps = {}
    for c in eng.step():
        comps[c.rid] = c
    r3 = eng.submit(PROMPTS[3], max_new_tokens=6)
    comps.update(eng.run())
    ref = _sequential(cfg, params, [PROMPTS[0], PROMPTS[3]], 6)
    assert [comps[r0].tokens, comps[r3].tokens] == ref


def test_fused_paged_greedy_matches_dense(model):
    """Fused decode composes with the paged KV pool + prefix reuse."""
    cfg, params = model
    ref = _sequential(cfg, params, PREFIX_PROMPTS, 5)
    eng = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4,
                 kv_page_size=4, decode_kernel="fused")
    assert eng.generate(PREFIX_PROMPTS, 5) == ref
    assert eng.pool.reused_pages_total > 0      # reuse actually happened


def test_fused_sampled_deterministic_and_chunk_invariant(model):
    """Fused sampled streams replay under the same seeds and are
    invariant to prefill chunking and batch composition (per-row keyed
    Gumbel noise, PRNG advanced per consumed token)."""
    cfg, params = model
    sp = SamplingParams(temperature=0.7, top_k=13, top_p=0.9, seed=5)
    a = Engine(cfg, params, max_len=64, batch_size=2,
               decode_kernel="fused").generate(PROMPTS, 6, sampling=sp)
    b = Engine(cfg, params, max_len=64, batch_size=2, prefill_chunk=4,
               decode_kernel="fused").generate(PROMPTS, 6, sampling=sp)
    assert a == b
    alone = Engine(cfg, params, max_len=64, batch_size=1,
                   decode_kernel="fused").generate(
        [PROMPTS[0]], 6, sampling=sp)[0]
    assert a[0] == alone


def test_fused_completions_carry_logprobs(model):
    """Completions report per-token logprobs on both paths; greedy
    logprobs agree between fused (online-LSE) and dense (log_softmax)."""
    cfg, params = model

    def comps_of(kernel):
        eng = Engine(cfg, params, max_len=64, batch_size=2,
                     decode_kernel=kernel)
        rids = [eng.submit(p, max_new_tokens=4) for p in PROMPTS[:2]]
        comps = eng.run()
        return [comps[r] for r in rids]

    dense = comps_of("dense")
    fused = comps_of("fused")
    for d, f in zip(dense, fused):
        assert d.tokens == f.tokens
        assert len(f.logprobs) == len(f.tokens) == 4
        np.testing.assert_allclose(d.logprobs, f.logprobs,
                                   rtol=1e-4, atol=1e-4)
        assert all(lp <= 0.0 for lp in f.logprobs)


def test_sample_tokens_pure_temperature_fast_path():
    """When no row filters (top_k==0, top_p>=1), sample_tokens must skip
    the sort yet draw exactly the tokens the filtered pipeline with no-op
    filters would draw (same categorical call on the same array)."""
    logits = jax.random.normal(jax.random.PRNGKey(3), (6, 97))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(6))
    temp = jnp.full((6,), 0.8)
    fast = sampling_mod.sample_tokens(
        logits, keys, temp, jnp.zeros((6,), jnp.int32), jnp.ones((6,)))
    # top_k == V keeps every token: the sort runs but filters nothing
    slow = sampling_mod.sample_tokens(
        logits, keys, temp, jnp.full((6,), 97, jnp.int32), jnp.ones((6,)))
    np.testing.assert_array_equal(fast, slow)
    want = jax.vmap(jax.random.categorical)(keys, logits / 0.8)
    np.testing.assert_array_equal(fast, want)


def test_fused_decode_hlo_has_no_batched_vocab_buffer(assert_memory_class):
    """The fused decode jit's optimized HLO must contain no (B, V)-class
    array, filtered or not — batch and vocab are enlarged until B·V
    dwarfs every legitimate buffer (weights, caches, kernel tiles). The
    dense step at the same geometry is the positive control."""
    from repro.analysis.checks import DENSE_CLASS, classify_hlo
    from repro.serve import engine as engine_mod

    cfg = _cfg(vocab_size=32768)
    b, max_len = 512, 16
    n, v, d = b, cfg.padded_vocab_size, cfg.d_model
    params_sds = jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    state_sds = jax.eval_shape(lambda: sched_mod.init_state(b, 8, 8))
    cache_sds = jax.eval_shape(lambda: T.init_cache(cfg, b, max_len))
    for wf in (False, True):
        text = engine_mod._engine_step_fused.lower(
            params_sds, cache_sds, state_sds, None, cfg=cfg,
            max_len=max_len, with_filter=wf).compile().as_text()
        assert_memory_class(text, n=n, v=v, d=d,
                            what=f"decode_fused(filter={wf})")
    text = engine_mod._engine_step.lower(
        params_sds, cache_sds, state_sds, None, cfg=cfg,
        max_len=max_len).compile().as_text()
    assert classify_hlo(text, n=n, v=v, d=d) == DENSE_CLASS


def test_fused_metrics_hbm_avoided_and_kernel_labels(model, monkeypatch):
    """The fused engine reports the per-step HBM bytes it did not move
    (host arithmetic — the one-device_get-per-step invariant must hold),
    and ITL/step-wall histograms carry a decode_kernel label while TTFT
    stays unlabeled."""
    from repro.obs import Registry

    cfg, params = model
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    reg = Registry()
    eng = Engine(cfg, params, max_len=64, batch_size=2, metrics=reg,
                 decode_kernel="fused")
    for p in PROMPTS[:2]:
        eng.submit(p, max_new_tokens=4)
    calls.clear()
    while eng.has_work():
        before = len(calls)
        done = eng.step()
        assert len(calls) - before == (2 if done else 1), \
            "fused path broke the one-transfer-per-step invariant"

    avoided = 2 * (cfg.padded_vocab_size * 4 - 8)
    assert reg.value("serve_decode_hbm_bytes_avoided") == avoided
    assert reg.value("serve_decode_hbm_bytes_avoided_total") > avoided
    assert reg.histogram("serve_itl_seconds",
                         {"decode_kernel": "fused"}).count == 2
    assert reg.histogram("serve_step_wall_seconds",
                         {"decode_kernel": "fused"}).count > 0
    assert reg.histogram("serve_ttft_seconds").count == 2   # unlabeled


def test_fused_metrics_do_not_recompile_fused_step(model):
    """Metrics stay host-side on the fused path too: no new entries in
    the fused jit cache when an instrumented engine reuses a warm
    config."""
    from repro.obs import Registry
    from repro.serve import engine as engine_mod

    cfg, params = model
    Engine(cfg, params, max_len=64, batch_size=2,
           decode_kernel="fused").generate(PROMPTS[:2], 2)
    before = engine_mod._engine_step_fused._cache_size()
    eng = Engine(cfg, params, max_len=64, batch_size=2,
                 metrics=Registry(), decode_kernel="fused")
    out = eng.generate(PROMPTS[:2], 2)
    assert engine_mod._engine_step_fused._cache_size() == before, \
        "enabling metrics recompiled the fused engine step"
    assert out == Engine(cfg, params, max_len=64, batch_size=2,
                         decode_kernel="fused").generate(PROMPTS[:2], 2)


def test_engine_rejects_unknown_decode_kernel(model):
    cfg, params = model
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=32, batch_size=1,
               decode_kernel="blocked")
