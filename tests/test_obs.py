"""Observability subsystem (repro.obs): metrics semantics, the no-op
disabled path, Prometheus exposition, the JSONL flight recorder, and the
kernel/train instrumentation contracts (DESIGN.md §8)."""

import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.obs import (NULL, JsonlSink, NullRegistry, Registry, Tracer,
                       exposition, read_jsonl, start_http_server)
from repro.obs import kernels as obs_kernels


# ---------------------------------------------------------------------------
# Instruments + registry.
# ---------------------------------------------------------------------------

def test_counter_monotonic():
    r = Registry()
    c = r.counter("reqs")
    c.inc()
    c.inc(2.5)
    assert r.value("reqs") == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = Registry().gauge("depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0


def test_histogram_cumulative_buckets():
    h = Registry().histogram("lat", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 50.0):
        h.observe(v)
    # Prometheus le semantics: each bound counts observations <= it;
    # the 50.0 lands only in the implicit +Inf (count)
    assert h.cumulative() == [(0.01, 1), (0.1, 3), (1.0, 4)]
    assert h.count == 5
    assert h.sum == pytest.approx(50.605)
    assert h.mean == pytest.approx(50.605 / 5)


def test_registry_memoizes_and_labels():
    r = Registry()
    assert r.counter("x") is r.counter("x")
    a = r.counter("x", {"impl": "cce"})
    b = r.counter("x", {"impl": "dense"})
    assert a is not b
    a.inc(1)
    b.inc(2)
    assert r.value("x", {"impl": "cce"}) == 1
    assert r.total("x") == 3          # across label sets (+ the bare one)
    assert r.total("never_registered") == 0.0


def test_registry_type_conflict_raises():
    r = Registry()
    r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")


def test_registry_snapshot_shape():
    r = Registry()
    r.counter("c").inc()
    r.histogram("h", buckets=(1.0,)).observe(0.5)
    snap = r.snapshot(ts=123.0)
    assert snap["type"] == "metrics" and snap["ts"] == 123.0
    by_name = {m["name"]: m for m in snap["metrics"]}
    assert by_name["c"]["kind"] == "counter" and by_name["c"]["value"] == 1
    assert by_name["h"]["kind"] == "histogram"
    assert by_name["h"]["buckets"] == [[1.0, 1]]
    json.dumps(snap)                  # JSON-ready, no numpy leakage


def test_null_registry_is_inert():
    assert NULL.enabled is False and isinstance(NULL, NullRegistry)
    i = NULL.counter("x")
    i.inc()
    i.set(5)
    i.observe(1.0)
    assert NULL.collect() == [] and NULL.total("x") == 0.0
    # instrumented code pattern: same call sites, zero registrations
    assert NULL.histogram("h") is NULL.gauge("g")


# ---------------------------------------------------------------------------
# Prometheus exposition + HTTP endpoint.
# ---------------------------------------------------------------------------

def test_exposition_format():
    r = Registry()
    r.counter("serve_tokens_total", {"kind": "gen"}).inc(5)
    r.gauge("depth").set(2)
    r.histogram("lat", buckets=(0.1, 1.0)).observe(0.05)
    text = exposition(r)
    assert '# TYPE serve_tokens_total counter' in text
    assert 'serve_tokens_total{kind="gen"} 5' in text
    assert "depth 2" in text
    assert 'lat_bucket{le="0.1"} 1' in text
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_count 1" in text


def test_metrics_http_endpoint():
    r = Registry()
    r.counter("up").inc()
    server = start_http_server(r, port=0)
    try:
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
        assert "up 1" in body
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# Flight recorder: JSONL sink + tracer.
# ---------------------------------------------------------------------------

def test_jsonl_sink_roundtrip(tmp_path):
    p = tmp_path / "t.jsonl"
    with JsonlSink(p) as sink:
        sink.write({"type": "event", "name": "a", "ts": 1.0})
        sink.write({"type": "event", "name": "b", "ts": 2.0})
    recs = read_jsonl(p)
    assert [r["name"] for r in recs] == ["a", "b"]


def test_tracer_lexical_and_keyed_spans(tmp_path):
    p = tmp_path / "t.jsonl"
    t = [0.0]
    tr = Tracer(JsonlSink(p), clock=lambda: t[0])
    with tr.span("compile", arch="x"):
        t[0] = 2.0
    tr.begin("request", key=7, ts=10.0, rid=7)
    tr.annotate(7, slot=1)
    tr.annotate(999)                        # unknown key: ignored
    tr.end(7, ts_end=13.5, n_tokens=4)
    tr.end(7)                               # double-end: ignored
    tr.event("tick", step=3)
    tr.sink.close()
    spans = {r["name"]: r for r in read_jsonl(p)}
    assert spans["compile"]["dur"] == pytest.approx(2.0)
    assert spans["compile"]["arch"] == "x"
    req = spans["request"]
    assert (req["ts"], req["dur"]) == (10.0, 3.5)
    assert req["slot"] == 1 and req["n_tokens"] == 4 and req["rid"] == 7
    assert spans["tick"]["type"] == "event"


def test_tracer_without_sink_is_noop():
    tr = Tracer(None)
    assert not tr.enabled
    with tr.span("x"):
        pass
    tr.begin("a", 1)
    tr.annotate(1, z=1)
    tr.end(1)
    tr.event("e")
    tr.snapshot(Registry())                 # nothing to write, no error


def test_sink_is_thread_safe(tmp_path):
    p = tmp_path / "t.jsonl"
    sink = JsonlSink(p)

    def work(i):
        for j in range(50):
            sink.write({"type": "event", "name": f"w{i}", "j": j})

    ts = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    sink.close()
    assert len(read_jsonl(p)) == 200        # no torn/interleaved lines


# ---------------------------------------------------------------------------
# Kernel gauges: Fig. 3's sparsity as a live metric (acceptance criterion:
# the gauge must match the kernels/ref.ref_block_live oracle on the
# peaked problem).
# ---------------------------------------------------------------------------

def test_cce_gauges_match_alg4_oracle():
    from repro.kernels import CCEConfig, ref

    E, C, x, _ = ref.peaked_problem(128, 64, 1024, hot=96, seed=0)
    cfg = CCEConfig(block_n=32, block_v=128)
    reg = Registry()
    vals = obs_kernels.record_cce_gauges(reg, E, C, x, cfg,
                                         alg4_oracle=True)
    # the gauge IS the bitmap fraction, and the opt-in oracle gauge IS the
    # exact paper-Alg.-4 statistic — recompute both independently here
    from repro.kernels import cce_bwd, ops
    bm, (bn, bv) = ops.live_block_bitmap(E, C, x, cfg)
    assert reg.value("cce_live_block_fraction") == pytest.approx(
        float(np.asarray(bm).mean()))
    rec = ref.ref_block_live(E, C, x, bn, bv, cfg.filter_eps
                             if cfg.filter_eps is not None
                             else cce_bwd.DEFAULT_FILTER_EPS,
                             softcap=cfg.softcap)
    assert reg.value("cce_live_block_fraction_alg4") == pytest.approx(
        float(rec.mean()))
    # superset contract: bitmap keeps everything Alg. 4 keeps
    assert not np.any(rec & ~np.asarray(bm))
    # the peaked problem must actually filter something, and the plan
    # gauges must reflect the resolved blocks
    assert 0.0 < vals["cce_live_block_fraction"] < 1.0
    assert (vals["cce_block_n"], vals["cce_block_v"]) == (32, 128)
    assert 0 < vals["cce_vmem_working_set_bytes"] \
        <= vals["cce_vmem_budget_bytes"]


def test_backend_memory_gauges_classify():
    reg = Registry()
    elems = obs_kernels.record_backend_memory_gauges(
        reg, n=2048, d=256, v=16384, impls=("cce_jax", "dense"))
    budget = reg.value("cce_backend_budget_elems")
    assert reg.value("cce_backend_in_class", {"impl": "cce_jax"}) == 1.0
    assert reg.value("cce_backend_in_class", {"impl": "dense"}) == 0.0
    assert elems["cce_jax"] <= budget < elems["dense"]


# ---------------------------------------------------------------------------
# Trainer structured records.
# ---------------------------------------------------------------------------

def test_trainer_emits_structured_records(tmp_path):
    import dataclasses

    import repro.configs as configs
    from repro.configs.base import TrainConfig
    from repro.train import Trainer

    cfg = dataclasses.replace(configs.get_reduced_config("gemma_2b"),
                              dtype="float32")
    reg = Registry()
    sink = JsonlSink(tmp_path / "train.jsonl")
    tr = Trainer(cfg, TrainConfig(total_steps=4, warmup_steps=1),
                 seq_len=16, global_batch=2, metrics=reg,
                 tracer=Tracer(sink))
    hist = tr.run(num_steps=4, log_every=2, log_fn=None)
    sink.close()

    assert len(hist) == 2                  # steps 2 and 4
    for m in hist:
        for k in ("step", "loss", "lr", "grad_norm", "n_tokens",
                  "step_wall_s", "tokens_per_s", "tokens_total"):
            assert k in m, k
    # 4 steps x 2 rows x 16 tokens, no ignored labels in synthetic data
    assert reg.value("train_tokens_total") == hist[-1]["tokens_total"] \
        == 4 * 2 * 16
    assert reg.value("train_steps_total") == 4
    assert reg.value("train_loss") == pytest.approx(hist[-1]["loss"])
    assert reg.histogram("train_step_wall_seconds").count == 2
    events = [r for r in read_jsonl(tmp_path / "train.jsonl")
              if r.get("name") == "train_step"]
    assert [e["step"] for e in events] == [2, 4]
