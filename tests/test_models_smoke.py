"""Per-architecture smoke tests: reduced config, one train step + decode on
CPU, asserting output shapes and finiteness (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
from repro.models import transformer as T

B, S = 2, 32


def _cfg(arch):
    return dataclasses.replace(configs.get_reduced_config(arch),
                               dtype="float32")


def _batch(cfg, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(
            ks[0], (B, S, cfg.d_model)) * 0.1
        if cfg.rope_sections is not None:
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0,
                                             cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            ks[2], (B, S // 2, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_forward_and_grad(arch):
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: T.train_loss(p, cfg, batch))(params)
    assert jnp.isfinite(loss), arch
    leaves = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in leaves), arch
    # a reasonable starting loss: close to ln|V| for random init
    assert 0.5 * jnp.log(cfg.vocab_size) < loss < 2.5 * jnp.log(
        cfg.vocab_size), (arch, float(loss))


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_decode_step(arch):
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, B, 64)
    tok = jnp.zeros((B, 1), jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        enc_out = T.encode(params, cfg, _batch(cfg))
    logits, new_cache = T.serve_step(params, cfg, cache, tok, 0,
                                     enc_out=enc_out)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), arch
    # cache structure is preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["llama3_2_3b", "rwkv6_3b",
                                  "recurrentgemma_9b", "h2o_danube3_4b"])
def test_decode_matches_teacher_forcing(arch):
    """Step-by-step decoding reproduces the parallel forward's logits."""
    cfg = _cfg(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0,
                              cfg.vocab_size)
    hidden, _, _ = T.lm_hidden(params, cfg, {"tokens": toks})
    C = T.classifier_matrix(params, cfg)
    ref_logits = hidden[:, -1].astype(jnp.float32) @ C.astype(
        jnp.float32).T

    cache = T.init_cache(cfg, B, 16)
    logits = None
    for t in range(8):
        logits, cache = T.serve_step(params, cfg, cache, toks[:, t:t + 1], t)
    err = jnp.max(jnp.abs(logits - ref_logits))
    assert err < 5e-3, (arch, float(err))


def test_tied_vs_untied_head():
    cfg = _cfg("gemma_2b")
    assert cfg.tie_embeddings
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    assert "head" not in params
    assert T.classifier_matrix(params, cfg) is params["embed"]


def test_moe_dispatch_parity_no_drops():
    """gather- and einsum-dispatch agree exactly when capacity is ample."""
    import repro.models.layers as L
    cfg = configs.get_reduced_config("olmoe_1b_7b").moe
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = L.init_moe(jax.random.PRNGKey(0), 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    o1, a1 = L.moe_mlp(params, x, cfg)
    o2, a2 = L.moe_mlp(params, x,
                       dataclasses.replace(cfg, dispatch="einsum"))
    assert jnp.max(jnp.abs(o1 - o2)) < 1e-4
    assert abs(float(a1 - a2)) < 1e-5
