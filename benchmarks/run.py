"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline/dry-run numbers live
in results/dryrun (produced by repro.launch.dryrun) and EXPERIMENTS.md.

``--json PATH`` additionally writes the perf-trajectory rows the modules
recorded via :func:`benchmarks.common.record` — schema-versioned
``{bench, config, geometry, flops, wall_s, memory_class, ts}`` rows,
stably sorted — and *merges* into an existing PATH: benches skipped via
``--only`` keep their previous rows instead of being clobbered. The
committed ``BENCH_kernels.json`` / ``BENCH_serve.json`` baselines are
regressed against fresh runs by ``benchmarks/perf_gate.py`` in CI.
``--only a,b`` restricts to named modules.
"""

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write recorded perf rows (e.g. BENCH_kernels."
                         "json); merges into PATH, keeping rows of "
                         "benches skipped via --only")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (default: all)")
    args = ap.parse_args()

    from benchmarks import (common, fig1_model_memory, fig3_softmax_sparsity,
                            fig4_convergence, loss_zoo_memory,
                            serve_throughput, table1_loss_memory,
                            tableA1_ignored_tokens,
                            tableA2_backward_breakdown, tableA3_more_models)
    modules = [
        ("table1", table1_loss_memory),
        ("loss_zoo", loss_zoo_memory),
        ("fig1_tableA4", fig1_model_memory),
        ("fig3", fig3_softmax_sparsity),
        ("fig4", fig4_convergence),
        ("tableA1", tableA1_ignored_tokens),
        ("tableA2", tableA2_backward_breakdown),
        ("tableA3", tableA3_more_models),
        ("serve", serve_throughput),
    ]
    if args.only:
        wanted = args.only.split(",")
        unknown = set(wanted) - {n for n, _ in modules}
        if unknown:
            sys.exit(f"unknown benchmark(s) {sorted(unknown)}; "
                     f"available: {[n for n, _ in modules]}")
        modules = [(n, m) for n, m in modules if n in wanted]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.run()
            print(f"{name}/_wall_s,{(time.time()-t0)*1e6:.0f},"
                  f"{time.time()-t0:.1f}s total")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.json:
        common.write_json(args.json)
        print(f"wrote {len(common.json_rows())} perf rows to {args.json}",
              file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
