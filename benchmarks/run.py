"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline/dry-run numbers live
in results/dryrun (produced by repro.launch.dryrun) and EXPERIMENTS.md.
"""

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig1_model_memory, fig3_softmax_sparsity,
                            fig4_convergence, loss_zoo_memory,
                            serve_throughput, table1_loss_memory,
                            tableA1_ignored_tokens,
                            tableA2_backward_breakdown, tableA3_more_models)
    modules = [
        ("table1", table1_loss_memory),
        ("loss_zoo", loss_zoo_memory),
        ("fig1_tableA4", fig1_model_memory),
        ("fig3", fig3_softmax_sparsity),
        ("fig4", fig4_convergence),
        ("tableA1", tableA1_ignored_tokens),
        ("tableA2", tableA2_backward_breakdown),
        ("tableA3", tableA3_more_models),
        ("serve", serve_throughput),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.run()
            print(f"{name}/_wall_s,{(time.time()-t0)*1e6:.0f},"
                  f"{time.time()-t0:.1f}s total")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
