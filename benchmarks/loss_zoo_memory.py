"""Memory-regression gate: every ``repro.losses`` entry through the
``cross_entropy`` dispatch layer must stay out of the N×V memory class.

For each registered loss this lowers (AOT, no execution) the value-and-grad
computation at a large-vocabulary size — *through the public
``repro.core.cross_entropy`` entry point, so the backend-registry dispatch
itself is under test* — and checks, via
``repro.analysis.hlo.array_shape_census`` on the optimized HLO, that **no
N×V-element buffer exists anywhere in the module** — i.e. the loss lives in
CCE's O(N·D + V·D) memory class. The dense baseline is lowered at the same
size as the control: its census is dominated by exactly that N×V buffer.

Also reports XLA's compiled temp+output allocation for the same
computations (from the one AOT compile per loss). Exits 1 on any
violation — CI runs this as the memory-regression gate, so a change to the
dispatch layer cannot silently reintroduce dense logits.

Run: PYTHONPATH=src python -m benchmarks.loss_zoo_memory [--paper]
  default size: N=4096, D=512, V=65536    (fast CI lowering;
                chosen so 4*max(N.D, V.D) << N.V and the verdict is sharp)
  --paper:      N=8192, D=2304, V=256000  (paper Table-1 configuration)
"""

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.analysis.checks.memclass import (DENSE_CLASS, census_budget,
                                            check_memory_class,
                                            classify_hlo)
from repro.core import cross_entropy
from repro.losses import get_loss, list_losses

# per-loss hyper-parameters exercised by the benchmark (defaults otherwise)
KWARGS = {"z_loss": {"z_weight": 1e-4}, "focal": {"gamma": 2.0},
          "label_smoothing": {"eps": 0.1}}


def _value_and_grad_fn(loss_name, impl, n, d, v):
    loss = get_loss(loss_name, **KWARGS.get(loss_name, {}))

    if loss_name == "seq_logprob":
        def f(E, C, x):  # scoring: grad of the summed sequence scores
            return jnp.sum(cross_entropy(
                E.reshape(8, n // 8, d), C, x.reshape(8, n // 8),
                loss=loss, impl=impl))
    else:
        def f(E, C, x):
            return cross_entropy(E, C, x, loss=loss, impl=impl,
                                 reduction="mean")

    return jax.value_and_grad(f, argnums=(0, 1))


def _lowered_text(fn, n, d, v, dtype=jnp.bfloat16):
    E = jax.ShapeDtypeStruct((n, d), dtype)
    C = jax.ShapeDtypeStruct((v, d), dtype)
    x = jax.ShapeDtypeStruct((n,), jnp.int32)
    comp = jax.jit(fn).lower(E, C, x).compile()
    return comp, comp.as_text()


def run(n=4096, d=512, v=65536):
    nv = n * v
    # the classifier's budget — everything a CCE-class loss may
    # legitimately hold (activations/grads N·D, classifier/grad V·D, the
    # scan twin's stacked dC) with 4x headroom; see checks.memclass.
    budget = census_budget(n, v, d)
    print(f"# loss_zoo_memory: N={n} D={d} V={v}  "
          f"NxV={nv:.3g} elems  budget={budget:.3g} elems  "
          f"(via repro.core.cross_entropy)")

    ok = True
    for name in list_losses():
        comp, text = _lowered_text(_value_and_grad_fn(name, "cce_jax",
                                                      n, d, v), n, d, v)
        finding = check_memory_class(text, n=n, v=v, d=d,
                                     what=f"loss_zoo/{name}")
        top_elems, top_desc = finding.data["census"][0]
        m = comp.memory_analysis()   # same compile: no second lowering
        live = m.temp_size_in_bytes + m.output_size_in_bytes
        ok &= finding.ok
        row(f"loss_zoo/{name}/cce_jax", 0,
            f"largest={top_desc}({top_elems:.3g} elems) "
            f"live={live/1e6:.0f}MB "
            f"{'O(N.D+V.D) OK' if finding.ok else 'N×V MATERIALIZED!'}")

    # control: the dense head at the same size must show the N×V buffer
    _, text = _lowered_text(_value_and_grad_fn("nll", "dense", n, d, v),
                            n, d, v)
    observed = classify_hlo(text, n=n, v=v, d=d)
    row("loss_zoo/nll/dense(control)", 0,
        f"observed {observed} "
        f"{'as expected' if observed == DENSE_CLASS else '— UNEXPECTED'}")

    print(f"# memory-class verdict: "
          f"{'ALL LOSSES IN CCE CLASS' if ok else 'FAILURES ABOVE'}")
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    import sys
    ap.add_argument("--paper", action="store_true",
                    help="paper Table-1 sizes (slower lowering)")
    args = ap.parse_args()
    ok = run(n=8192, d=2304, v=256000) if args.paper else run()
    sys.exit(0 if ok else 1)
