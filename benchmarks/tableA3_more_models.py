"""Paper Table A3: loss-layer memory across the paper's additional models.

Same protocol as table1 (AOT compiled allocation at N=8192 tokens, bf16)
for Gemma 2 9B/27B, Mistral NeMo, Phi 3.5 Mini, Qwen 2.5 7B/32B — the
dense baseline vs every platform-suitable CCE-class backend from the
``repro.backends`` registry (not a hardcoded impl pair). The paper's
App. C.2 observation to reproduce: as |V|/D falls, CCE's time edge shrinks
but the memory win stays roughly an order of magnitude — here the memory
ratio is the measurable part.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import row, static_mem_bytes
from repro import backends
from repro.core import cross_entropy

N_TOKENS = 8192

# (name, |V|, D) from paper Table A3
MODELS = [
    ("gemma2_9b", 256_000, 3584),
    ("gemma2_27b", 256_000, 4608),
    ("mistral_nemo", 131_072, 5120),
    ("phi35_mini", 32_064, 3072),
    ("qwen25_7b", 152_064, 3584),
    ("qwen25_32b", 152_064, 5120),
]


def _methods():
    """dense control + every CCE-memory-class backend suited to this
    platform (AOT-analyzable), straight from the registry."""
    platform = jax.default_backend()
    names = ["dense"]
    names += [b.name for b in backends.all_backends()
              if b.memory_class == "O(N·D + V·D)"
              and not b.owns_reduction
              and platform in b.preferred_platforms]
    return names


def _loss_fn(impl):
    def f(E, C, x):
        return jnp.sum(cross_entropy(E, C, x, impl=impl))
    return f


def _grad_fn(impl):
    return jax.grad(_loss_fn(impl), argnums=(0, 1))


def run():
    methods = _methods()
    if len(methods) < 2:    # no platform-preferred CCE-class backend
        methods.append("cce_jax")   # portable twin runs anywhere
    print(f"# tableA3: compiled loss-layer allocation at N=8192 (bf16), "
          f"additional paper models; methods={methods}")
    cce_name = methods[1]   # the registry's CCE-class twin for this host
    for name, vocab, d in MODELS:
        sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)
        xi = jax.ShapeDtypeStruct((N_TOKENS,), jnp.int32)
        mem = {}
        for impl in methods:
            m_l = static_mem_bytes(_loss_fn(impl), sds(N_TOKENS, d),
                                   sds(vocab, d), xi)["total_live"]
            m_g = static_mem_bytes(_grad_fn(impl), sds(N_TOKENS, d),
                                   sds(vocab, d), xi)["total_live"]
            mem[impl] = (m_l, m_g)
            row(f"tableA3/{name}/{impl}", 0,
                f"loss={m_l/1e6:.0f}MB loss+grad={m_g/1e6:.0f}MB")
        ratio = mem["dense"][0] / max(mem[cce_name][0], 1.0)
        row(f"tableA3/{name}/loss_mem_ratio", 0,
            f"dense/{cce_name}={ratio:.0f}x (|V|/D={vocab/d:.0f})")


if __name__ == "__main__":
    run()
