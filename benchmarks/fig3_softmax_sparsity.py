"""Paper Fig. 3 + §5.2: sorted softmax probabilities vanish below the
gradient-filtering threshold within ~50 ranks, making the softmax matrix
block-sparse. We train a reduced model briefly on structured synthetic data
and measure the sorted per-rank average probability, the block-level
sparsity the backward kernels exploit, and — new — how the
forward-emitted live-block bitmap (``filter_stats="fwd_bitmap"``,
DESIGN.md §7) compares against the paper's recompute statistic on the same
trained model (the bitmap must be a conservative superset: it may keep a
block Alg. 4 would drop, never the reverse)."""

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, row
import repro.configs as configs
from repro.configs.base import TrainConfig
from repro.kernels import cce_fwd, ref
from repro.kernels.cce_bwd import DEFAULT_FILTER_EPS
from repro.models import transformer as T
from repro.train import Trainer


def run(steps: int = 60):
    cfg = dataclasses.replace(configs.get_reduced_config("gemma_2b"),
                              dtype="float32", vocab_size=2048)
    tcfg = TrainConfig(total_steps=steps, warmup_steps=5,
                       learning_rate=1e-3)
    tr = Trainer(cfg, tcfg, seq_len=64, global_batch=8)
    tr.run(num_steps=steps, log_every=10**9, log_fn=None)

    batch = {k: jnp.asarray(v) for k, v in tr.data.batch_at(steps).items()}
    hidden, _, _ = T.lm_hidden(tr.params, cfg, batch)
    E = hidden.reshape(-1, cfg.d_model)
    C = T.classifier_matrix(tr.params, cfg)
    S = ref.ref_softmax(E, C)                      # (N, V)
    S_sorted = jnp.sort(S, axis=-1)[:, ::-1]
    avg = np.asarray(jnp.mean(S_sorted, axis=0))

    eps = DEFAULT_FILTER_EPS
    below = int(np.argmax(avg < eps)) if np.any(avg < eps) else -1
    frac_nonzero = float(jnp.mean(S >= eps))
    for r in (0, 1, 4, 16, 64, 256, 1024):
        if r < avg.size:
            row(f"fig3/avg_prob_rank_{r}", 0, f"{avg[r]:.3e}")
    row("fig3/rank_below_eps", 0, f"{below} (paper: ~50)")
    row("fig3/frac_entries_above_eps", 0,
        f"{frac_nonzero:.5f} (paper: <0.0002 at |V|=256k)")

    # block-level skippability at the kernel's block_v granularity
    bv = 128
    nv = cfg.vocab_size // bv
    blocks = S.reshape(S.shape[0], nv, bv)
    live = jnp.max(blocks, axis=-1) >= eps         # (N, nv)
    row("fig3/block_live_fraction", 0,
        f"{float(jnp.mean(live)):.4f} (fraction of (token,vblock) pairs "
        f"the backward must compute)")

    # ---- fwd-bitmap vs recompute statistic on the trained model ---------
    # The bitmap is taken at the kernel's real block grid: (block_n rows x
    # block_v vocab) blocks, one bit each — what the backward passes gate
    # their tile recompute on under filter_stats="fwd_bitmap".
    bn = 64
    x = jnp.asarray(batch["labels"]).reshape(-1)
    safe_x = jnp.where(x < 0, 0, x)
    *_, bm = cce_fwd.cce_forward_pallas(
        E, C, safe_x, block_n=bn, block_v=bv, emit_bitmap=True,
        filter_eps=eps, interpret=True)
    bm = np.asarray(bm) != 0

    rec = ref.ref_block_live(E, C, safe_x, bn, bv, eps)
    dropped = np.sum(rec & ~bm)
    assert dropped == 0, "fwd bitmap dropped a block Alg. 4 keeps"
    row("fig3/bitmap_live_fraction", 0,
        f"{bm.mean():.4f} (fwd-emitted bitmap at ({bn},{bv}) blocks)")
    row("fig3/recompute_live_fraction", 0,
        f"{rec.mean():.4f} (paper Alg. 4 statistic at the same grid)")
    row("fig3/bitmap_dropped_live_blocks", 0,
        f"{int(dropped)} (must be 0: the bitmap is a conservative "
        f"superset)")
    geom = (f"N={E.shape[0]} D={cfg.d_model} V={cfg.vocab_size} "
            f"bn={bn} bv={bv}")
    record("fig3", "bitmap_live_fraction", geometry=geom, flops=None,
           memory_class="O(N·V/(bn·bv)) bits",
           live_frac=float(bm.mean()))
    record("fig3", "recompute_live_fraction", geometry=geom,
           live_frac=float(rec.mean()))


if __name__ == "__main__":
    run()
