"""Paper Fig. 1 / Table A4: per-model training-memory breakdown and the max
attainable batch size with vs. without CCE, on a 16-GPU(80GB) FSDP setup.

Pure accounting per the paper's Appendix D method:
  * weights+opt+grad = params * 4 states * 2 bytes (bf16)
  * activations      = layers * hidden * tokens * 2 (ckpt boundaries)
  * logits           = tokens * |V| * 4 (f32)
CCE removes the logits term entirely (O(N + |V|) scratch, ~1 MB).

Models: the paper's Table A4 list (public configs) + our ten assigned
architectures for comparison.
"""

from benchmarks.common import row
import repro.configs as configs

# (name, params, layers, hidden, vocab) — paper Table A4 models
PAPER_MODELS = [
    ("GPT 2", 131e6, 12, 768, 50257),
    ("GPT Neo (1.3B)", 1.3e9, 24, 2048, 50257),
    ("GPT Neo (2.7B)", 2.6e9, 32, 2560, 50257),
    ("Gemma (2B)", 2.4e9, 18, 2048, 256000),
    ("Gemma 2 (27B)", 26e9, 46, 4608, 256000),
    ("Gemma 2 (2B)", 2.5e9, 26, 2304, 256000),
    ("Llama 2 (13B)", 12.4e9, 40, 5120, 32000),
    ("Llama 2 (7B)", 6.4e9, 32, 4096, 32000),
    ("Llama 3 (70B)", 67e9, 80, 8192, 128256),
    ("Llama 3 (8B)", 7.7e9, 32, 4096, 128256),
    ("Mistral 7B", 6.9e9, 32, 4096, 32000),
    ("Phi 1.5", 1.35e9, 24, 2048, 51200),
    ("Qwen 1.5 (7B)", 7.4e9, 32, 4096, 151936),
]

TOKENS = 65536
GPUS, PER_GPU = 16, 75e9   # 80GB minus 5GB runtime buffer (paper App. D)


def _mem(params, layers, hidden, vocab, tokens):
    weights = params * 4 * 2
    acts = layers * hidden * tokens * 2
    logits = tokens * vocab * 4
    return weights, acts, logits


def _max_batch(params, layers, hidden, vocab, with_cce):
    weights = params * 4 * 2
    per_tok = layers * hidden * 2 + (0 if with_cce else vocab * 4)
    return (GPUS * PER_GPU - weights) / per_tok


def run():
    print("# fig1/tableA4: memory breakdown (MB @65536 tokens) and max "
          "batch (tokens, 16x80GB FSDP)")
    for name, p, l, h, v in PAPER_MODELS:
        w, a, lg = _mem(p, l, h, v, TOKENS)
        b0 = _max_batch(p, l, h, v, False)
        b1 = _max_batch(p, l, h, v, True)
        row(f"fig1/{name.replace(' ', '_')}", 0,
            f"logits={lg/1e6:.0f}MB acts={a/1e6:.0f}MB "
            f"weights+opt={w/1e6:.0f}MB max_batch {b0/1e6:.2f}M->"
            f"{b1/1e6:.2f}M ({b1/b0:.1f}x)")

    print("# assigned architectures, same accounting")
    for arch in configs.ASSIGNED:
        cfg = configs.get_config(arch)
        p = cfg.param_count()
        w, a, lg = _mem(p, cfg.num_layers, cfg.d_model, cfg.vocab_size,
                        TOKENS)
        b0 = _max_batch(p, cfg.num_layers, cfg.d_model, cfg.vocab_size,
                        False)
        b1 = _max_batch(p, cfg.num_layers, cfg.d_model, cfg.vocab_size,
                        True)
        row(f"fig1/{arch}", 0,
            f"params={p/1e9:.2f}B logits={lg/1e6:.0f}MB "
            f"max_batch {b0/1e6:.2f}M->{b1/1e6:.2f}M ({b1/b0:.1f}x)")


if __name__ == "__main__":
    run()
