"""Paper Table 1: memory & time of the cross-entropy layer per method.

Memory column: XLA compiled allocation (temp+output) at the paper's EXACT
configuration — N=8192 tokens, |V|=256,000, D=2304 (Gemma-2 2B) — via AOT
lowering, no execution. This is the apples-to-apples analogue of the
paper's CUDA peak-memory numbers (their A100 measurement; ours is the XLA
buffer assignment for the same computation).

Time column: wall-clock at a reduced size (N=2048, D=512, |V|=16384, CPU)
for the pure-jnp implementations; relative ordering is what transfers.

The method list is the ``repro.backends`` registry itself — a backend
registered tomorrow shows up as a row here with no edit — filtered by
platform preference (the Pallas ``cce`` row is measured analytically below
on CPU, where interpret-mode AOT at paper size is meaningless; on TPU it
joins the table).
"""

import jax
import jax.numpy as jnp

from benchmarks.common import problem, row, static_mem_bytes, wall_us
from repro import backends
from repro.core import cross_entropy
from repro.kernels.ops import choose_blocks

PAPER_N, PAPER_D, PAPER_V = 8192, 2304, 256000
SMALL_N, SMALL_D, SMALL_V = 2048, 512, 16384

LABEL = {"cce": "CCE (ours, Pallas kernels)",
         "cce_jax": "CCE (ours, scan twin)",
         "liger": "Liger-style (fwd grads)",
         "chunked": "TorchTune-style (8 chunks)",
         "dense": "Baseline (materialized logits)"}


def _methods():
    platform = jax.default_backend()
    return [b for b in backends.all_backends()
            if not b.preferred_platforms
            or platform in b.preferred_platforms]


def _loss_fn(be):
    # reduction-owning backends (liger) return the scalar themselves
    red = "mean" if be.owns_reduction else "none"

    def f(E, C, x):
        out = cross_entropy(E, C, x, impl=be.name, reduction=red)
        return jnp.sum(out) if red == "none" else out
    return f


def _grad_fn(be):
    f = _loss_fn(be)
    return jax.grad(f, argnums=(0, 1))


def run():
    print("# table1: memory at paper size (N=8192, D=2304, V=256000), "
          "bf16; time at reduced size (CPU wall); methods = "
          "repro.backends registry")
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)
    xi = jax.ShapeDtypeStruct((PAPER_N,), jnp.int32)
    E, C, x = problem(SMALL_N, SMALL_D, SMALL_V, jnp.bfloat16)

    lower = 2 * (PAPER_N * PAPER_D + PAPER_V * PAPER_D)  # dE+dC bf16
    row("table1/lower_bound_grad_buffers_MB", 0, f"{lower/1e6:.0f}MB")

    for be in _methods():
        mem_l = static_mem_bytes(_loss_fn(be),
                                 sds(PAPER_N, PAPER_D),
                                 sds(PAPER_V, PAPER_D), xi)
        mem_g = static_mem_bytes(_grad_fn(be),
                                 sds(PAPER_N, PAPER_D),
                                 sds(PAPER_V, PAPER_D), xi)
        t_l = wall_us(_loss_fn(be), E, C, x)
        t_g = wall_us(_grad_fn(be), E, C, x)
        row(f"table1/{be.name}/loss", t_l,
            f"live={mem_l['total_live']/1e6:.0f}MB")
        row(f"table1/{be.name}/loss+grad", t_g,
            f"live={mem_g['total_live']/1e6:.0f}MB "
            f"({LABEL.get(be.name, be.description)}; "
            f"declared {be.memory_class})")

    # CCE Pallas kernel VMEM working set at paper size (analytic, DESIGN §2)
    bn, bv = choose_blocks(PAPER_N, PAPER_V, PAPER_D, 2)
    vmem = (2 * (bn + bv) * PAPER_D * 2 + bn * bv * 4
            + max(bn, bv) * PAPER_D * 4)
    row("table1/cce_pallas/vmem_working_set", 0,
        f"{vmem/1e6:.1f}MB blocks=({bn}x{bv}) "
        f"hbm_extra={(PAPER_N*4*2)/1e6:.1f}MB(lse+pick)")


if __name__ == "__main__":
    run()
