"""Paper Fig. 4/5: training with CCE (gradient filtering on) is
indistinguishable from the dense baseline. We train the same reduced model
with both heads from identical seeds and report the loss-curve divergence.
Also checks CCE-Kahan-FullC (the paper's pretraining-exact variant)."""

import dataclasses

import numpy as np

from benchmarks.common import row
import repro.configs as configs
from repro.configs.base import TrainConfig
from repro.train import Trainer

STEPS = 80


def _curve(loss_impl, arch="gemma_2b", seed=11):
    cfg = dataclasses.replace(configs.get_reduced_config(arch),
                              dtype="float32", loss_impl=loss_impl)
    tcfg = TrainConfig(total_steps=STEPS, warmup_steps=5,
                       learning_rate=1e-3, seed=seed)
    tr = Trainer(cfg, tcfg, seq_len=48, global_batch=4)
    hist = tr.run(num_steps=STEPS, log_every=5, log_fn=None)
    return np.array([h["loss"] for h in hist])


def run():
    dense = _curve("dense")
    cce = _curve("cce")
    cce_jax = _curve("cce_jax")
    row("fig4/final_loss_dense", 0, f"{dense[-1]:.4f}")
    row("fig4/final_loss_cce", 0, f"{cce[-1]:.4f}")
    row("fig4/max_curve_divergence_cce_vs_dense", 0,
        f"{np.max(np.abs(cce - dense)):.2e} (paper: indistinguishable)")
    row("fig4/max_curve_divergence_ccejax_vs_dense", 0,
        f"{np.max(np.abs(cce_jax - dense)):.2e}")
    assert dense[-1] < dense[0], "training must reduce loss"


if __name__ == "__main__":
    run()
