"""Paper Appendix B / Table A1: ignored tokens (padding, prompts) can be
*removed before* the loss instead of masked after — a pure win for every
method. We benchmark the loss+grad wall time with and without compaction at
45% ignored tokens, and verify exactness."""

import jax
import jax.numpy as jnp

from benchmarks.common import problem, row, wall_us
from repro.core import cross_entropy
from repro.core.compaction import compact_valid_tokens

N, D, V = 2048, 512, 16384
IGNORE_FRAC = 0.45


def run():
    E, C, x = problem(N, D, V, jnp.float32, seed=3,
                      ignore_frac=IGNORE_FRAC)
    capacity = int(N * (1 - IGNORE_FRAC) * 1.15)  # static headroom

    def loss_masked(E, C, x):
        return jnp.sum(cross_entropy(E, C, x, impl="cce_jax"))

    def loss_compact(E, C, x):
        E2, x2 = compact_valid_tokens(E, x, capacity)
        return jnp.sum(cross_entropy(E2, C, x2, impl="cce_jax"))

    # exactness (paper: "no change to the loss/gradient")
    l1 = jax.jit(loss_masked)(E, C, x)
    l2 = jax.jit(loss_compact)(E, C, x)
    g1 = jax.jit(jax.grad(loss_masked))(E, C, x)
    g2 = jax.jit(jax.grad(loss_compact))(E, C, x)
    row("tableA1/loss_delta", 0, f"{abs(float(l1 - l2)):.2e}")
    row("tableA1/grad_delta", 0,
        f"{float(jnp.max(jnp.abs(g1 - g2))):.2e}")

    for name, fn in (("masked", loss_masked), ("compacted", loss_compact)):
        t_l = wall_us(fn, E, C, x)
        t_g = wall_us(jax.grad(fn, argnums=(0, 1)), E, C, x)
        row(f"tableA1/{name}/loss", t_l, "")
        row(f"tableA1/{name}/loss+grad", t_g,
            f"ignored={IGNORE_FRAC:.0%} capacity={capacity}")


if __name__ == "__main__":
    run()
