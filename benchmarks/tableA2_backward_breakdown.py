"""Paper Table A2: where the CCE backward pass spends its work.

On CPU we cannot profile TPU wall time, so the breakdown is in FLOPs from
the HLO analyzer on the compiled backward at the paper's Gemma-2 geometry:
logit recomputation (Cᵀ E), softcap chain, dE matmul, dC matmul. The
paper's A100 numbers for reference: recompute 43.2%, dE 29.6%, dC 17.3%.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.analysis import hlo as hlo_an
from repro.core import cross_entropy

N, D, V = 4096, 2304, 32768  # paper geometry, vocab scaled to CPU compile


def _flops(fn, *sds):
    comp = jax.jit(fn).lower(*sds).compile()
    return hlo_an.analyze(comp.as_text())["flops"]


def run():
    sds_e = jax.ShapeDtypeStruct((N, D), jnp.bfloat16)
    sds_c = jax.ShapeDtypeStruct((V, D), jnp.bfloat16)
    sds_x = jax.ShapeDtypeStruct((N,), jnp.int32)

    def fwd(E, C, x):
        return jnp.sum(cross_entropy(E, C, x, impl="cce_jax",
                                     softcap=30.0))

    def fwd_bwd(E, C, x):
        return jax.grad(fwd, argnums=(0, 1))(E, C, x)

    f_fwd = _flops(fwd, sds_e, sds_c, sds_x)
    f_all = _flops(fwd_bwd, sds_e, sds_c, sds_x)
    f_bwd = f_all - f_fwd

    # analytic components of the backward (2*N*V*D each)
    mm = 2.0 * N * V * D
    row("tableA2/total_bwd_GFLOP", 0, f"{f_bwd/1e9:.1f}")
    row("tableA2/recompute_share", 0,
        f"{mm/f_bwd:.2%} (paper: 43.2% of time)")
    row("tableA2/dE_share", 0, f"{mm/f_bwd:.2%} (paper: 29.6%)")
    row("tableA2/dC_share", 0, f"{mm/f_bwd:.2%} (paper: 17.3%)")
    row("tableA2/pointwise_share", 0,
        f"{max(0.0, (f_bwd - 3*mm))/f_bwd:.2%} "
        f"(softmax+softcap chain; paper: ~10%)")
    row("tableA2/fwd_GFLOP", 0, f"{f_fwd/1e9:.1f} (1x NVD matmul + LSE)")


if __name__ == "__main__":
    run()
