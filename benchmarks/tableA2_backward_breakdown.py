"""Paper Table A2: where the CCE backward pass spends its work — and the
four-way backward-strategy comparison (PR: fused single-pass backward +
forward-emitted block-sparsity maps, DESIGN.md §7).

Part 1 (paper parity): on CPU we cannot profile TPU wall time, so the
breakdown is in FLOPs from the HLO analyzer on the compiled backward of the
scan twin at the paper's Gemma-2 geometry: logit recomputation (Cᵀ E),
softcap chain, dE matmul, dC matmul. The paper's A100 numbers for
reference: recompute 43.2%, dE 29.6%, dC 17.3%.

Part 2 (this repo's knobs): the executed backward FLOPs of every
``CCEConfig.bwd`` x ``filter_stats`` combination. Block-skipping is
data-dependent control flow, so the HLO census (which charges both branches
of a conditional) cannot see it; instead the census calibrates the
full-sweep per-matmul cost M and the *measured* live-block fractions of the
real Pallas kernels on a post-training-like peaked problem scale it:

    two_pass + recompute   2M + 2 f_rec M   (recompute paid on dead blocks)
    two_pass + fwd_bitmap  4 f_bm M         (dead blocks skip the recompute)
    fused    + recompute    M + 2 f_rec M   (one recompute, both matmuls)
    fused    + fwd_bitmap  3 f_bm M         (fewest executed FLOPs)

with f_bm >= f_rec (the bitmap is a conservative superset). FLOPs are not
the whole story: the fused dC accumulates through HBM (read+write of the
f32 (V, D) array once per n-block) where two_pass writes each dC block
once from VMEM, so an analytic HBM-traffic estimate per combination is
reported alongside — on bandwidth-bound geometries two_pass can win
wall-clock, which is exactly why ``--cce-bwd`` stays a knob. Interpret-mode
wall time of the actual kernels is reported too (relative numbers only —
CPU interpret, but the @pl.when skips are real control flow there).
Rows are recorded for ``run.py --json`` (BENCH_kernels.json).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, row
from repro.analysis import hlo as hlo_an
from repro.core import cross_entropy
from repro.kernels import CCEConfig, choose_blocks, linear_cross_entropy_pallas
from repro.kernels import cce_bwd, cce_fwd, ref

N, D, V = 4096, 2304, 32768  # paper geometry, vocab scaled to CPU compile

# reduced geometry for executing the real (interpret-mode) Pallas kernels
MN, MD, MV, MBN, MBV = 128, 64, 1024, 32, 128

COMBOS = [("two_pass", "recompute"), ("two_pass", "fwd_bitmap"),
          ("fused", "recompute"), ("fused", "fwd_bitmap")]


def _flops(fn, *sds):
    comp = jax.jit(fn).lower(*sds).compile()
    return hlo_an.analyze(comp.as_text())["flops"]


def _live_fractions(E, C, x):
    """(f_bitmap, f_recompute) block-live fractions: the fwd-emitted bitmap
    from the real kernel, and the paper-Alg.4 max|S - onehot| statistic
    (oracle shared with the kernel tests)."""
    eps = cce_bwd.DEFAULT_FILTER_EPS
    *_, bm = cce_fwd.cce_forward_pallas(
        E, C, x, block_n=MBN, block_v=MBV, emit_bitmap=True,
        filter_eps=eps, interpret=True)
    bm = np.asarray(bm) != 0
    rec = ref.ref_block_live(E, C, x, MBN, MBV, eps)
    assert not np.any(rec & ~bm), "bitmap dropped a block Alg. 4 keeps"
    return float(bm.mean()), float(rec.mean())


def _traffic_model(bn, bv, itemsize=2):
    """Analytic HBM bytes per backward at the paper geometry. Input-tile
    streams are charged in full — the Pallas pipeline DMAs blocks whether
    or not @pl.when skips the compute — so filtering changes FLOPs, not
    traffic. Per pass over the (n, v) grid: the C stream re-reads V·D per
    n-block, the E stream re-reads N·D per v-block. two_pass runs two such
    passes and writes each dE/dC block once from VMEM; fused runs one pass
    but streams the f32 dC array read+write once per n-block."""
    nn, nv = -(-N // bn), -(-V // bv)
    c_stream = nn * V * D * itemsize
    e_stream = nv * N * D * itemsize
    outs = N * D * itemsize + V * D * itemsize
    two_pass = 2 * (c_stream + e_stream) + outs
    fused = (c_stream + e_stream) + N * D * itemsize + 2 * nn * V * D * 4
    return two_pass, fused


def _wall_s(cfg_kwargs, E, C, x, g):
    cfg = CCEConfig(block_n=MBN, block_v=MBV, **cfg_kwargs)

    def loss(e, c):
        return jnp.sum(linear_cross_entropy_pallas(e, c, x, cfg) * g)

    f = jax.jit(jax.grad(loss, argnums=(0, 1)))
    jax.block_until_ready(f(E, C))                       # compile
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(f(E, C))
    return (time.perf_counter() - t0) / 3


def run():
    sds_e = jax.ShapeDtypeStruct((N, D), jnp.bfloat16)
    sds_c = jax.ShapeDtypeStruct((V, D), jnp.bfloat16)
    sds_x = jax.ShapeDtypeStruct((N,), jnp.int32)

    def fwd(E, C, x):
        return jnp.sum(cross_entropy(E, C, x, impl="cce_jax",
                                     softcap=30.0))

    def fwd_bwd(E, C, x):
        return jax.grad(fwd, argnums=(0, 1))(E, C, x)

    f_fwd = _flops(fwd, sds_e, sds_c, sds_x)
    f_all = _flops(fwd_bwd, sds_e, sds_c, sds_x)
    f_bwd = f_all - f_fwd

    # analytic components of the backward (2*N*V*D each)
    mm = 2.0 * N * V * D
    row("tableA2/total_bwd_GFLOP", 0, f"{f_bwd/1e9:.1f}")
    row("tableA2/recompute_share", 0,
        f"{mm/f_bwd:.2%} (paper: 43.2% of time)")
    row("tableA2/dE_share", 0, f"{mm/f_bwd:.2%} (paper: 29.6%)")
    row("tableA2/dC_share", 0, f"{mm/f_bwd:.2%} (paper: 17.3%)")
    row("tableA2/pointwise_share", 0,
        f"{max(0.0, (f_bwd - 3*mm))/f_bwd:.2%} "
        f"(softmax+softcap chain; paper: ~10%)")
    row("tableA2/fwd_GFLOP", 0, f"{f_fwd/1e9:.1f} (1x NVD matmul + LSE)")
    paper_geom = f"N={N} D={D} V={V}"
    record("tableA2", "scan_twin_fwd", geometry=paper_geom, flops=f_fwd,
           memory_class="O(N·D + V·D)")
    record("tableA2", "scan_twin_bwd_full", geometry=paper_geom,
           flops=f_bwd, memory_class="O(N·D + V·D)")

    # ---- four-way bwd strategy comparison (executed-FLOP model) ----------
    E, C, x, g = ref.peaked_problem(MN, MD, MV, hot=96, seed=0)
    f_bm, f_rec = _live_fractions(E, C, x)
    row("tableA2/live_frac_fwd_bitmap", 0,
        f"{f_bm:.4f} (blocks the bitmap keeps)")
    row("tableA2/live_frac_recompute", 0,
        f"{f_rec:.4f} (blocks Alg. 4 keeps; bitmap is a superset)")

    model = {
        ("two_pass", "recompute"): 2 * mm + 2 * f_rec * mm,
        ("two_pass", "fwd_bitmap"): 4 * f_bm * mm,
        ("fused", "recompute"): mm + 2 * f_rec * mm,
        ("fused", "fwd_bitmap"): 3 * f_bm * mm,
    }
    bn_p, bv_p = choose_blocks(N, V, D, 2, accum_rows=2, emit_bitmap=True)
    tp_bytes, fu_bytes = _traffic_model(bn_p, bv_p)
    traffic = {c: (fu_bytes if c[0] == "fused" else tp_bytes)
               for c in COMBOS}
    walls = {}
    for bwd, stats in COMBOS:
        walls[(bwd, stats)] = _wall_s(
            dict(bwd=bwd, filter_stats=stats), E, C, x, g)
    for (bwd, stats), fl in model.items():
        w = walls[(bwd, stats)]
        row(f"tableA2/bwd_{bwd}_{stats}", w * 1e6,
            f"{fl/1e9:.1f} GFLOP / ~{traffic[(bwd, stats)]/1e9:.1f} GB HBM "
            f"@ paper geometry; wall {w*1e3:.0f}ms (interpret, reduced "
            f"geometry)")
        record("tableA2", f"bwd={bwd},filter_stats={stats}",
               geometry=paper_geom, flops=fl,
               wall_s=w, memory_class="O(N·D + V·D)",
               hbm_bytes=traffic[(bwd, stats)],
               live_frac=f_bm if stats == "fwd_bitmap" else f_rec)

    # acceptance gates: fwd_bitmap strictly fewer executed backward FLOPs
    # than recompute for both strategies, and fused+fwd_bitmap the measured
    # best (the CCEConfig default) — CI runs this module, so a regression
    # that flips the winner fails loudly instead of shipping a stale
    # default.
    assert model[("two_pass", "fwd_bitmap")] < model[("two_pass", "recompute")]
    assert model[("fused", "fwd_bitmap")] < model[("fused", "recompute")]
    best = min(model, key=model.get)
    assert best == ("fused", "fwd_bitmap"), (best, model)
    row("tableA2/measured_best", 0,
        f"bwd={best[0]},filter_stats={best[1]} by executed FLOPs + "
        f"interpret wall (CCEConfig default). Caveat: fused streams the "
        f"f32 dC through HBM ({fu_bytes/1e9:.1f} GB vs {tp_bytes/1e9:.1f} "
        f"GB) — on bandwidth-bound geometries prefer --cce-bwd two_pass")

    # forward bitmap-emission overhead (same kernels, interpret wall)
    def fwd_only(emit):
        t0 = time.perf_counter()
        outs = cce_fwd.cce_forward_pallas(
            E, C, x, block_n=MBN, block_v=MBV, emit_bitmap=emit,
            filter_eps=cce_bwd.DEFAULT_FILTER_EPS if emit else None,
            interpret=True)
        jax.block_until_ready(outs)
        return time.perf_counter() - t0

    w0, w1 = fwd_only(False), fwd_only(True)
    nvb = -(-MV // MBV)
    row("tableA2/fwd_bitmap_overhead", 0,
        f"bitmap adds {(-(-MN // MBN)) * nvb * 4} bytes / "
        f"{(w1-w0)*1e3:+.0f}ms interpret wall")
    reduced_geom = f"N={MN} D={MD} V={MV} bn={MBN} bv={MBV}"
    record("tableA2", "fwd_pallas", geometry=reduced_geom, wall_s=w0,
           flops=f_fwd, memory_class="O(N·D + V·D)")
    record("tableA2", "fwd_pallas+bitmap", geometry=reduced_geom,
           wall_s=w1, flops=f_fwd, memory_class="O(N·D + V·D)")


if __name__ == "__main__":
    run()
