"""Serving benchmark: continuous batching vs. the legacy lockstep engine,
plus the scoring path's memory-class gate.

Decode: a mixed-prompt-length workload (short chats next to long
documents, staggered arrivals) is served twice —

  * **lockstep** (the pre-scheduler engine, reproduced below): all
    prompts admitted up front, one shared timeline, a Python loop that
    syncs ``int(nxt[i])`` PER ROW PER STEP, the whole batch retiring at
    the speed of its slowest row;
  * **continuous**: the slot scheduler — per-row ``cache_index``,
    device-side sampling/stopping, one host sync per step, finished rows
    replaced mid-flight from the queue —

and a third time with **chunked prefill** enabled (``prefill_chunk=16``):
prompts are ingested up to 16 tokens per fused prefill+decode step, so a
48-token prompt reaches its first generated token in 3 steps instead of
48 (token streams unchanged).

The same workload shape is then served at an enlarged vocabulary with
the **fused decode kernel** (``decode_kernel="fused"``: projection and
sampling stream ``C^T h`` blockwise, the ``(B, V)`` logit matrix never
reaches HBM) against the dense fallback — reporting tok/s, mean
inter-token latency (ITL, from the engine's labeled
``serve_itl_seconds`` histogram) and the sampler's per-step HBM
footprint (dense: the ``B x V_pad`` f32 logit buffer; fused: the 8-byte
token+logprob pair per row). The fused row carries memory_class
``O(N·D + V·D)``, the dense row ``O(N·V)`` — the perf gate pins both so
the default serve path can never silently re-materialize batched vocab
logits.

Speculative decoding is measured on a **peaked mixed workload** (blocks
zeroed so the tied head greedily repeats — deterministic low-entropy
continuations): the fused engine with ``spec_k`` in {2, 4} and the
zero-cost n-gram drafter vs. the same engine with speculation off.
Rows carry tok/s, ITL, mean accepted length, acceptance rate and the
within-run speedup (``speedup_vs_fused``) — the perf gate pins the
acceptance rate and the spec_k=4 speedup floor.

Reported: wall-clock tokens/s and mean time-to-first-token (TTFT); the
chunked-prefill row includes its TTFT cut over one-token prefill. Every
variant is also recorded for ``run.py --only serve --json
BENCH_serve.json`` — the committed serving-perf trajectory the CI perf
gate compares against.

Scoring: ``repro.launch.serve.check_scoring_memory_class`` AOT-lowers the
``cross_entropy(..., loss="seq_logprob")`` scorer at an enlarged
vocabulary and verifies via ``analysis/hlo.array_shape_census`` that no
N×V buffer exists — the O(N·D + V·D) class, same gate discipline as
``loss_zoo_memory``. Exit 1 on violation (CI runs this).

Run: PYTHONPATH=src python -m benchmarks.serve_throughput
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, row
import repro.configs as configs
from repro.models import transformer as T
from repro.obs import metrics as M
from repro.serve import Engine


class LockstepEngine:
    """The pre-scheduler engine, kept verbatim as the baseline: greedy
    only, all prompts up front, per-row host syncs, no slot reuse."""

    def __init__(self, cfg, params, *, max_len=512, batch_size=8):
        self.cfg, self.params = cfg, params
        self.max_len, self.batch_size = max_len, batch_size
        self._step = jax.jit(functools.partial(T.serve_step, cfg=cfg))

    def generate(self, prompts, max_new_tokens=16):
        assert len(prompts) <= self.batch_size
        b = len(prompts)
        cache = T.init_cache(self.cfg, b, self.max_len)
        outputs = [[] for _ in range(b)]
        tok = jnp.asarray([[p[0]] for p in prompts], jnp.int32)
        t = 0
        while min(len(o) for o in outputs) < max_new_tokens:
            logits, cache = self._step(params=self.params, cache=cache,
                                       tokens=tok, cache_index=t)
            nxt = jnp.argmax(logits, axis=-1)
            next_tok = []
            for i, p in enumerate(prompts):
                if t + 1 < len(p):
                    next_tok.append(p[t + 1])
                else:
                    tok_i = int(nxt[i])        # the per-row host sync
                    if len(outputs[i]) < max_new_tokens:
                        outputs[i].append(tok_i)
                    next_tok.append(tok_i)
            tok = jnp.asarray(next_tok, jnp.int32)[:, None]
            t += 1
            if t >= self.max_len - 1:
                break
        return outputs


def _workload(vocab, n_requests=8, max_prompt=48, seed=0):
    """Mixed prompt lengths (3..max_prompt) with 4..14 new tokens each —
    the skew that makes lockstep waves retire at their slowest row."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(3, max_prompt + 1))
        reqs.append((list(rng.integers(1, vocab, size=plen)),
                     int(rng.integers(4, 15))))
    return reqs


def _bench_lockstep(cfg, params, reqs, max_len, slots):
    eng = LockstepEngine(cfg, params, max_len=max_len, batch_size=slots)
    eng.generate([[1, 2]] * min(slots, len(reqs)), 2)     # compile warmup
    t0 = time.time()
    total, ttfts = 0, []
    # lockstep admits at most `slots` prompts at a time, waves of batches;
    # within a wave everything decodes max(max_new) tokens (its semantics)
    for i in range(0, len(reqs), slots):
        wave = reqs[i:i + slots]
        wave_new = max(m for _, m in wave)
        outs = eng.generate([p for p, _ in wave], max_new_tokens=wave_new)
        # the whole wave lands at once, and every request was submitted at
        # t0: TTFT for a wave member is the time until its wave returns
        ttfts += [time.time() - t0] * len(wave)
        total += sum(min(len(o), m) for o, (_, m) in zip(outs, wave))
    return total, time.time() - t0, float(np.mean(ttfts))


def _bench_continuous(cfg, params, reqs, max_len, slots,
                      prefill_chunk=1, engine_kw=None):
    # warmup on a THROWAWAY engine: the step/admission jits are module-
    # level so the timed engine inherits the compilations, while its pool
    # stats and prefix registry start clean (warmup traffic must not
    # pollute the measured hit rate)
    warm = Engine(cfg, params, max_len=max_len, batch_size=slots,
                  prefill_chunk=prefill_chunk, **(engine_kw or {}))
    warm.generate([[1, 2] * max(1, prefill_chunk)] * len(reqs), 2)
    # the timed engine gets its own metrics registry so per-row ITL (the
    # labeled serve_itl_seconds histogram) is readable after the run
    eng = Engine(cfg, params, max_len=max_len, batch_size=slots,
                 prefill_chunk=prefill_chunk, metrics=M.Registry(),
                 **(engine_kw or {}))
    rids = [eng.submit(p, max_new_tokens=m) for p, m in reqs]
    t0 = time.time()
    comps = eng.run()
    dt = time.time() - t0
    total = sum(len(comps[r].tokens) for r in rids)
    ttfts = [comps[r].first_token_time - comps[r].submit_time
             for r in rids if comps[r].first_token_time]
    return total, dt, float(np.mean(ttfts)), eng


def _peaked_workload(vocab, n_requests=12, seed=7):
    """Short prompts, longer continuations — the decode-dominated shape
    where multi-token acceptance pays. Served against a PEAKED model
    (blocks zeroed, tied head) whose greedy continuation is maximally
    predictable, standing in for low-entropy traffic (code completion,
    boilerplate, retrieval-grounded answers)."""
    rng = np.random.default_rng(seed)
    return [(list(rng.integers(1, vocab, size=int(rng.integers(3, 9)))),
             int(rng.integers(16, 25))) for _ in range(n_requests)]


def _prefix_workload(vocab, n_requests=12, prefix_len=24, tail_lo=4,
                     tail_hi=9, seed=1):
    """Many requests sharing one long system prompt — the dominant traffic
    shape at scale, and the one copy-free prefix reuse targets."""
    rng = np.random.default_rng(seed)
    sys_prompt = list(rng.integers(1, vocab, size=prefix_len))
    reqs = []
    for _ in range(n_requests):
        tail = list(rng.integers(1, vocab,
                                 size=int(rng.integers(tail_lo, tail_hi))))
        reqs.append((sys_prompt + tail, int(rng.integers(4, 9))))
    return reqs


def run(arch="llama3_2_3b", n_requests=12, slots=4, max_len=80,
        prefill_chunk=16):
    cfg = dataclasses.replace(configs.get_reduced_config(arch),
                              dtype="float32")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    reqs = _workload(cfg.vocab_size, n_requests=n_requests)

    tl, dl, fl = _bench_lockstep(cfg, params, reqs, max_len, slots)
    tc, dc, fc, _ = _bench_continuous(cfg, params, reqs, max_len, slots)
    tp, dp, fp, _ = _bench_continuous(cfg, params, reqs, max_len, slots,
                                      prefill_chunk=prefill_chunk)
    row(f"serve/{arch}/lockstep", dl / max(tl, 1) * 1e6,
        f"{tl / dl:.1f} tok/s ttft={fl * 1e3:.0f}ms "
        f"({n_requests} reqs, {slots} slots)")
    row(f"serve/{arch}/continuous", dc / max(tc, 1) * 1e6,
        f"{tc / dc:.1f} tok/s ttft={fc * 1e3:.0f}ms "
        f"speedup={dl / dc:.2f}x")
    row(f"serve/{arch}/chunked_prefill", dp / max(tp, 1) * 1e6,
        f"{tp / dp:.1f} tok/s ttft={fp * 1e3:.0f}ms "
        f"(chunk={prefill_chunk}) ttft_cut={fc / max(fp, 1e-9):.2f}x")
    # perf-trajectory rows (run.py --json BENCH_serve.json); wall_s is the
    # full workload wall so the gate tracks end-to-end serving time
    geom = f"arch={arch} reqs={n_requests} slots={slots} max_len={max_len}"
    for config, (tok, dt, ttft) in [
            ("lockstep", (tl, dl, fl)),
            ("continuous", (tc, dc, fc)),
            (f"chunked_prefill@{prefill_chunk}", (tp, dp, fp))]:
        record("serve", config, geometry=geom, wall_s=dt,
               memory_class="O(N·D + V·D)", tok_s=tok / dt,
               ttft_ms=ttft * 1e3, tokens=tok)

    # fused decode kernel vs the dense fallback, at an ENLARGED vocab
    # (the regime the kernel exists for — at the reduced test vocab the
    # dense argmax is trivially cheap and the comparison says nothing):
    # identical greedy workload and chunked prefill, so the delta
    # isolates the sampler. ITL comes from each engine's labeled
    # serve_itl_seconds histogram.
    dk_vocab = 32768
    dcfg = dataclasses.replace(cfg, vocab_size=dk_vocab)
    dparams = T.init_lm(jax.random.PRNGKey(0), dcfg)
    dreqs = _workload(dcfg.vocab_size, n_requests=n_requests)
    dgeom = (f"arch={arch} reqs={n_requests} slots={slots} "
             f"max_len={max_len} vocab={dk_vocab}")
    td, dd, fd, deng = _bench_continuous(
        dcfg, dparams, dreqs, max_len, slots,
        prefill_chunk=prefill_chunk, engine_kw={"decode_kernel": "dense"})
    tf, df, ff, feng = _bench_continuous(
        dcfg, dparams, dreqs, max_len, slots,
        prefill_chunk=prefill_chunk, engine_kw={"decode_kernel": "fused"})
    itl_d = deng.metrics.histogram(
        "serve_itl_seconds", {"decode_kernel": "dense"}).mean
    itl_f = feng.metrics.histogram(
        "serve_itl_seconds", {"decode_kernel": "fused"}).mean
    # sampler-side HBM per decode step: dense materializes the full
    # (slots, V_pad) f32 logit matrix; fused writes one (token, logprob)
    # pair per row (4 + 4 bytes) and nothing vocab-shaped
    dense_bytes = slots * dcfg.padded_vocab_size * 4
    fused_bytes = slots * 8
    avoided = float(feng.metrics.value("serve_decode_hbm_bytes_avoided"))
    row(f"serve/{arch}/decode_dense", dd / max(td, 1) * 1e6,
        f"{td / dd:.1f} tok/s itl={itl_d * 1e3:.2f}ms "
        f"sampler={dense_bytes / 1e6:.2f}MB/step (vocab={dk_vocab})")
    row(f"serve/{arch}/decode_fused", df / max(tf, 1) * 1e6,
        f"{tf / df:.1f} tok/s itl={itl_f * 1e3:.2f}ms "
        f"sampler={fused_bytes}B/step "
        f"hbm_avoided={avoided / 1e6:.2f}MB/step")
    assert tf == td, (
        f"fused greedy decode produced {tf} tokens vs dense {td} — the "
        f"paths must be token-identical on a greedy workload")
    record("serve", "decode_dense", geometry=dgeom, wall_s=dd,
           memory_class="O(N·V)", tok_s=td / dd, ttft_ms=fd * 1e3,
           tokens=td, itl_ms=itl_d * 1e3, sampler_hbm_bytes=dense_bytes)
    record("serve", "decode_fused", geometry=dgeom, wall_s=df,
           memory_class="O(N·D + V·D)", tok_s=tf / df, ttft_ms=ff * 1e3,
           tokens=tf, itl_ms=itl_f * 1e3, sampler_hbm_bytes=fused_bytes,
           hbm_bytes_avoided_per_step=avoided)

    # speculative decoding on a peaked mixed workload: zeroing the block
    # weights leaves hidden = norm(embed[tok]), so the tied head's greedy
    # argmax repeats the current token — deterministic, maximally
    # predictable continuations, the regime speculation exists for. The
    # zero-cost n-gram drafter proposes the repeat, CCE verification
    # accepts whole windows, and each engine round emits up to spec_k+1
    # tokens for ONE host sync and one (B·S)-row fused sweep (never a
    # (B, K, V) logit block). tok/s is compared against the same fused
    # engine with speculation off on the identical workload — the gap is
    # purely the per-step overhead the collapsed step count amortizes.
    pparams = {k: (jax.tree_util.tree_map(jnp.zeros_like, v)
                   if k == "blocks" else v) for k, v in params.items()}
    sreqs = _peaked_workload(cfg.vocab_size, n_requests=n_requests)
    sgeom = (f"arch={arch} reqs={n_requests} slots={slots} "
             f"max_len={max_len} workload=peaked")
    tk0, dk0, fk0, keng = _bench_continuous(
        cfg, pparams, sreqs, max_len, slots, prefill_chunk=prefill_chunk,
        engine_kw={"decode_kernel": "fused"})
    itl_k0 = keng.metrics.histogram(
        "serve_itl_seconds", {"decode_kernel": "fused"}).mean
    row(f"serve/{arch}/decode_fused_peaked", dk0 / max(tk0, 1) * 1e6,
        f"{tk0 / dk0:.1f} tok/s itl={itl_k0 * 1e3:.2f}ms "
        f"(peaked workload, spec off)")
    record("serve", "decode_fused", geometry=sgeom, wall_s=dk0,
           memory_class="O(N·D + V·D)", tok_s=tk0 / dk0,
           ttft_ms=fk0 * 1e3, tokens=tk0, itl_ms=itl_k0 * 1e3)
    for sk in (2, 4):
        tsp, dsp, fsp, seng = _bench_continuous(
            cfg, pparams, sreqs, max_len, slots,
            engine_kw={"decode_kernel": "fused", "spec_k": sk})
        # greedy speculation is exact: token-for-token identical output
        assert tsp == tk0, (
            f"spec_k={sk} emitted {tsp} tokens vs {tk0} without "
            f"speculation — greedy acceptance must be lossless")
        acc_len = seng.metrics.histogram(
            "serve_spec_accepted_len", {"spec_k": sk}).mean
        acc_rate = float(seng.metrics.value("serve_spec_accept_rate"))
        # the peaked model's continuation is deterministic; a drafter or
        # verifier regression shows up here before it shows up as wall
        assert acc_rate > 0.9, (
            f"spec_k={sk} acceptance rate {acc_rate:.2f} on the peaked "
            f"workload — draft/verify pipeline regressed")
        itl_s = seng.metrics.histogram(
            "serve_itl_seconds",
            {"decode_kernel": "fused", "spec_k": sk}).mean
        speedup = (tsp / dsp) / (tk0 / dk0)
        row(f"serve/{arch}/spec_decode@{sk}", dsp / max(tsp, 1) * 1e6,
            f"{tsp / dsp:.1f} tok/s itl={itl_s * 1e3:.2f}ms "
            f"acc_len={acc_len:.2f} acc_rate={acc_rate:.2f} "
            f"speedup={speedup:.2f}x")
        record("serve", f"spec_decode@{sk}", geometry=sgeom, wall_s=dsp,
               memory_class="O(N·D + V·D)", tok_s=tsp / dsp,
               ttft_ms=fsp * 1e3, tokens=tsp, itl_ms=itl_s * 1e3,
               mean_accepted_len=acc_len, spec_accept_rate=acc_rate,
               speedup_vs_fused=speedup)

    # shared-prefix workload: dense vs paged-with-prefix-reuse, both with
    # chunked prefill so the TTFT delta isolates the reuse itself (the
    # paged engine skips already-resident prefix pages at admission)
    page = 8
    preqs = _prefix_workload(cfg.vocab_size, n_requests=n_requests)
    ts, ds, fs, _ = _bench_continuous(cfg, params, preqs, max_len, slots,
                                      prefill_chunk=prefill_chunk)
    tg, dg, fg, peng = _bench_continuous(
        cfg, params, preqs, max_len, slots, prefill_chunk=prefill_chunk,
        engine_kw={"kv_page_size": page})
    st = peng.pool.stats()
    assert st["prefix_hit_rate"] > 0, (
        "shared-prefix workload produced no prefix-page reuse — the kvpool "
        "prefix registry regressed")
    row(f"serve/{arch}/shared_prefix_dense", ds / max(ts, 1) * 1e6,
        f"{ts / ds:.1f} tok/s ttft={fs * 1e3:.0f}ms")
    row(f"serve/{arch}/shared_prefix_paged", dg / max(tg, 1) * 1e6,
        f"{tg / dg:.1f} tok/s ttft={fg * 1e3:.0f}ms "
        f"hit_rate={st['prefix_hit_rate']:.2f} "
        f"peak_pages={st['peak_pages']}/{peng.pool.num_pages} "
        f"ttft_cut={fs / max(fg, 1e-9):.2f}x")
    record("serve", "shared_prefix_dense", geometry=geom, wall_s=ds,
           memory_class="O(N·D + V·D)", tok_s=ts / ds,
           ttft_ms=fs * 1e3, tokens=ts)
    record("serve", f"shared_prefix_paged@{page}", geometry=geom,
           wall_s=dg, memory_class="O(N·D + V·D)", tok_s=tg / dg,
           ttft_ms=fg * 1e3, tokens=tg,
           prefix_hit_rate=st["prefix_hit_rate"],
           peak_kv_pages=st["peak_pages"])

    # scoring-path memory gate (same discipline as loss_zoo_memory)
    from repro.launch.serve import check_scoring_memory_class
    ok = check_scoring_memory_class(cfg, impl="cce_jax", quiet=True)
    row(f"serve/{arch}/scoring_memclass", 0,
        "O(N.D+V.D) OK" if ok else "NxV MATERIALIZED!")
    record("serve", "scoring", geometry=geom,
           memory_class="O(N·D + V·D)" if ok else "O(N·V)")
    if not ok:
        raise AssertionError(
            "scoring path materialized an NxV buffer — the CCE lowering "
            "of serve/scoring.py regressed")
    return ok


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    sys.exit(0 if run() else 1)
