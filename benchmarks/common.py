"""Shared benchmark helpers.

Two measurement modes (this container is CPU-only; TPU is the target):
  * ``wall_us``    — wall-clock of a jit'd callable (relative comparisons
    between same-backend jnp implementations are meaningful on CPU);
  * ``static_mem`` — XLA's compiled temp+output allocation for the op at
    the *paper's exact sizes* via AOT lowering (no execution, honest even
    for shapes that would not fit in RAM).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Machine-readable perf-trajectory rows (benchmarks/run.py --json). Each row
# is one measured kernel/loss variant; the committed BENCH_*.json files are
# the repo's perf trajectory, and benchmarks/perf_gate.py regresses fresh
# runs against them in CI.
# ---------------------------------------------------------------------------

#: Perf-file schema: ``{"schema": N, "rows": [...]}``. Bump when row keys
#: change meaning; readers also accept the legacy bare-list format.
SCHEMA_VERSION = 1

_JSON_ROWS: list[dict] = []


def record(bench: str, config: str, *, geometry: str | None = None,
           flops: float | None = None, wall_s: float | None = None,
           memory_class: str | None = None, **extra) -> None:
    """Append one ``{bench, config, geometry, flops, wall_s, memory_class,
    ts}`` row to the in-process perf log (written out by ``run.py --json``).
    ``geometry`` names the problem size (e.g. ``"N=4096 V=32768 D=1024"``)
    so the perf gate only ever compares like with like."""
    _JSON_ROWS.append({"bench": bench, "config": config,
                       "geometry": geometry, "flops": flops,
                       "wall_s": wall_s, "memory_class": memory_class,
                       "ts": round(time.time(), 3), **extra})


def json_rows() -> list[dict]:
    return list(_JSON_ROWS)


def row_key(r: dict) -> tuple:
    """Stable identity+sort key: (bench, config, geometry)."""
    return (r.get("bench") or "", r.get("config") or "",
            r.get("geometry") or "")


def read_json(path: str) -> list[dict]:
    """Rows from a perf file — schema-versioned dict or legacy bare list."""
    with open(path) as f:
        doc = json.load(f)
    return doc if isinstance(doc, list) else doc.get("rows", [])


def write_json(path: str) -> None:
    """Write ``{"schema": ..., "rows": [...]}`` — rows stably sorted by
    (bench, config, geometry) with ``sort_keys`` so reruns diff cleanly.

    Merges into an existing file: benches re-recorded this run replace
    their old rows; rows from benches *not* run (e.g. skipped via
    ``run.py --only``) are kept, so a targeted rerun never clobbers the
    rest of the trajectory."""
    rows = list(_JSON_ROWS)
    fresh = {r["bench"] for r in rows}
    if os.path.exists(path):
        rows += [r for r in read_json(path) if r.get("bench") not in fresh]
    rows.sort(key=row_key)
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA_VERSION, "rows": rows}, f,
                  indent=1, sort_keys=True, default=float)
        f.write("\n")


def wall_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    jfn = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(jfn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def static_mem_bytes(fn, *arg_shapes) -> dict:
    """Compile (AOT) and report XLA's allocation sizes for the op."""
    comp = jax.jit(fn).lower(*arg_shapes).compile()
    m = comp.memory_analysis()
    return {
        "temp": m.temp_size_in_bytes,
        "output": m.output_size_in_bytes,
        "argument": m.argument_size_in_bytes,
        "total_live": m.temp_size_in_bytes + m.output_size_in_bytes,
    }


def problem(n, d, v, dtype=jnp.float32, seed=0, ignore_frac=0.0):
    from repro.kernels.ref import IGNORE_INDEX
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    E = (jax.random.normal(ks[0], (n, d)) * 0.7).astype(dtype)
    C = (jax.random.normal(ks[1], (v, d)) * 0.5).astype(dtype)
    x = jax.random.randint(ks[2], (n,), 0, v)
    if ignore_frac:
        x = jnp.where(jax.random.uniform(ks[3], (n,)) < ignore_frac,
                      IGNORE_INDEX, x)
    return E, C, x


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
