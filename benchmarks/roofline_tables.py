"""Emit the EXPERIMENTS.md §Dry-run / §Roofline tables from results JSONs.

Usage: PYTHONPATH=src python -m benchmarks.roofline_tables [--dir results/dryrun]
Prints GitHub-flavored markdown to stdout.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ORDER = ["seamless_m4t_medium", "starcoder2_7b", "llama3_2_3b",
         "h2o_danube3_4b", "gemma_2b", "qwen2_vl_7b", "recurrentgemma_9b",
         "olmoe_1b_7b", "qwen2_moe_a2_7b", "rwkv6_3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_):
    cells = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        d = json.load(open(f))
        if d.get("tag"):
            continue
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def fmt_s(x):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x:.4f}"


def dryrun_table(cells) -> str:
    out = ["| arch | shape | mesh | status | bytes/device | HLO GFLOP/dev |"
           " coll GB/dev | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for arch in ORDER:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                d = cells.get((arch, shape, mesh))
                if d is None:
                    out.append(f"| {arch} | {shape} | {mesh} | MISSING |"
                               " | | | |")
                    continue
                if d.get("skipped"):
                    out.append(f"| {arch} | {shape} | {mesh} | skip"
                               f" ({d['skipped'][:40]}) | | | | |")
                    continue
                if not d["ok"]:
                    out.append(f"| {arch} | {shape} | {mesh} | **FAIL** |"
                               " | | | |")
                    continue
                mem = d["memory"]["per_device_total"] / 1e9
                fl = d["hlo"]["flops_per_device"] / 1e9
                cb = d["hlo"]["collective_bytes_per_device"] / 1e9
                out.append(
                    f"| {arch} | {shape} | {mesh} | ok | {mem:.2f} GB |"
                    f" {fl:,.0f} | {cb:.1f} | {d.get('compile_s', 0):.0f} |")
    return "\n".join(out)


def roofline_table(cells, mesh="single") -> str:
    out = ["| arch | shape | compute s | memory s | collective s |"
           " dominant | MODEL/HLO FLOPs | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for arch in ORDER:
        for shape in SHAPES:
            d = cells.get((arch, shape, mesh))
            if d is None or d.get("skipped") or not d.get("ok"):
                continue
            r = d["roofline"]
            dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
            frac = r["compute_s"] / dom if dom else 0.0
            out.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} |"
                f" {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} |"
                f" {r['dominant']} | {r['useful_ratio']:.2f} |"
                f" {frac:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--table", default="both",
                    choices=("dryrun", "roofline", "both"))
    args = ap.parse_args()
    cells = load(args.dir)
    if args.table in ("dryrun", "both"):
        print("### Dry-run cells\n")
        print(dryrun_table(cells))
        print()
    if args.table in ("roofline", "both"):
        print("### Roofline (single-pod, per step)\n")
        print(roofline_table(cells))


if __name__ == "__main__":
    main()
