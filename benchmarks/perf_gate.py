"""Perf gate: compare a fresh ``run.py --json`` output against a committed
baseline (the in-repo perf trajectory, BENCH_kernels.json / BENCH_serve.json).

  PYTHONPATH=src python -m benchmarks.perf_gate BASELINE FRESH \
      [--wall-tol 1.5] [--strict-wall]

Rows are matched by identity key ``(bench, config, geometry)``. Checks
per matched pair:

  * **memory_class** — HARD FAIL (exit 1) on any regression. Classes are
    ranked ``O(N·D + V·D)`` < ``O(N/K·V)`` < ``O(N·V)`` (< unknown); a
    fresh row may improve its class, never worsen it. This is the paper's
    central claim and must not drift.
  * **wall_s** — WARN ONLY by default: wall clock on shared CI runners is
    noisy (and the kernels run in interpret mode on CPU), so a fresh wall
    beyond ``--wall-tol`` x baseline prints a warning; ``--strict-wall``
    upgrades it to a failure for controlled machines.
  * **prefix_hit_rate** — HARD FAIL when a baseline row carries a
    positive hit rate and the fresh row's is zero/absent: shared-prefix
    page reuse went silently dead.
  * **spec_accept_rate** — HARD FAIL when a baseline row's speculative
    acceptance rate drops below 80% of baseline or vanishes: on the
    peaked benchmark workload acceptance is deterministic, so a drop is
    a draft/verify pipeline break, not noise.
  * **speedup_vs_fused** — HARD FAIL when a baseline row demonstrated
    the >=1.3x speculative-decoding speedup and the fresh row falls
    below 1.3x. The ratio is measured within one run (spec vs. spec-off
    back to back on the same machine), so it is runner-speed-invariant
    and safe to gate hard, unlike raw ``wall_s``.

Baseline rows with no fresh counterpart are reported (the fresh run may
legitimately have been restricted via ``--only``); fresh rows with no
baseline are listed as new so the baseline can be re-committed.
"""

from __future__ import annotations

import argparse

from benchmarks.common import read_json, row_key
# Single source of truth for memory-class ordering: lower rank = strictly
# better; unknown/None classes rank worst so a fresh row can never dodge
# the gate by dropping the field.
from repro.analysis.checks.memclass import class_rank


def compare(baseline: list[dict], fresh: list[dict], *,
            wall_tol: float = 1.5) -> dict:
    """-> {failures, warnings, missing, new, matched} (lists of strings,
    except ``matched``: int)."""
    base = {row_key(r): r for r in baseline}
    new = {row_key(r): r for r in fresh}
    out = {"failures": [], "warnings": [], "missing": [], "new": [],
           "matched": 0}
    for key in sorted(set(base) & set(new)):
        b, f = base[key], new[key]
        out["matched"] += 1
        name = "/".join(k for k in key if k)
        bc, fc = b.get("memory_class"), f.get("memory_class")
        if class_rank(fc) > class_rank(bc):
            out["failures"].append(
                f"{name}: memory_class regressed {bc!r} -> {fc!r}")
        bw, fw = b.get("wall_s"), f.get("wall_s")
        if bw and fw and fw > wall_tol * bw:
            out["warnings"].append(
                f"{name}: wall_s {bw:.4g} -> {fw:.4g} "
                f"({fw / bw:.2f}x > {wall_tol:.2f}x tolerance)")
        # prefix reuse — HARD FAIL when a baseline row demonstrated
        # copy-free prefix hits and the fresh run shows none: the kvpool
        # registry silently matching nothing is a correctness-adjacent
        # perf cliff, not CI noise
        bh, fh = b.get("prefix_hit_rate"), f.get("prefix_hit_rate")
        if bh and not fh:
            out["failures"].append(
                f"{name}: prefix_hit_rate regressed {bh:.3g} -> "
                f"{fh if fh is not None else 'absent'} (prefix reuse lost)")
        # speculative acceptance — HARD FAIL below the floor: the peaked
        # benchmark workload accepts deterministically (rate ~1.0), so a
        # drop means the draft/verify pipeline broke, not noise
        ba, fa = b.get("spec_accept_rate"), f.get("spec_accept_rate")
        if ba and (fa is None or fa < 0.8 * ba):
            out["failures"].append(
                f"{name}: spec_accept_rate regressed {ba:.3g} -> "
                f"{fa if fa is not None else 'absent'} "
                f"(speculative acceptance lost)")
        # speculative speedup — HARD FAIL when a baseline row demonstrated
        # the 1.3x multi-token-acceptance win and the fresh row loses it.
        # speedup_vs_fused is a WITHIN-RUN ratio (spec vs. spec-off on the
        # same machine, back to back), so unlike raw wall_s it is robust
        # to runner speed and safe to gate hard.
        bs, fs = b.get("speedup_vs_fused"), f.get("speedup_vs_fused")
        if bs and bs >= 1.3 and (fs is None or fs < 1.3):
            out["failures"].append(
                f"{name}: speculative speedup regressed {bs:.3g}x -> "
                f"{f'{fs:.3g}x' if fs is not None else 'absent'} "
                f"(below the 1.3x floor)")
    out["missing"] = ["/".join(k for k in key if k)
                      for key in sorted(set(base) - set(new))]
    out["new"] = ["/".join(k for k in key if k)
                  for key in sorted(set(new) - set(base))]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_*.json")
    ap.add_argument("fresh", help="freshly produced run.py --json output")
    ap.add_argument("--wall-tol", type=float, default=1.5,
                    help="warn when fresh wall_s exceeds this multiple "
                         "of baseline (default 1.5)")
    ap.add_argument("--strict-wall", action="store_true",
                    help="treat wall_s warnings as failures")
    args = ap.parse_args(argv)

    res = compare(read_json(args.baseline), read_json(args.fresh),
                  wall_tol=args.wall_tol)
    print(f"perf gate: {res['matched']} rows matched against "
          f"{args.baseline}")
    for m in res["missing"]:
        print(f"  note: baseline row not in fresh run: {m}")
    for m in res["new"]:
        print(f"  note: new row (not in baseline): {m}")
    for w in res["warnings"]:
        print(f"  WARN: {w}")
    for f in res["failures"]:
        print(f"  FAIL: {f}")
    if res["matched"] == 0:
        print("  FAIL: no rows matched — wrong files?")
        return 1
    if res["failures"] or (args.strict_wall and res["warnings"]):
        return 1
    print("perf gate: OK"
          + (f" ({len(res['warnings'])} wall warnings)"
             if res["warnings"] else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
