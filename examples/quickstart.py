"""Quickstart: Cut Cross-Entropy (CCE) in five minutes.

Shows the core contribution of the paper as a drop-in JAX op:

  1. ``cross_entropy(E, C, x, impl=...)`` — identical numerics across every
     registered backend (Pallas CCE, the scan twin, and the paper's
     dense/chunked/liger baseline rows), discovered from the
     ``repro.backends`` registry instead of a hardcoded list.
  2. Gradients match, including through the custom VJP with gradient
     filtering (the paper's 3.5x backward speedup trick).
  3. The memory story: what each backend materializes (its declared
     ``memory_class``).
  4. One extra keyword — ``loss=`` — swaps in any registry loss;
     ``mesh=`` (see examples/distributed_cce.py) shards the same call.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import backends
from repro.core import cross_entropy
from repro.kernels.ops import CCEConfig


def main():
    key = jax.random.PRNGKey(0)
    k_e, k_c, k_x = jax.random.split(key, 3)

    # A Gemma-2-2B-shaped loss layer, scaled down to run instantly on CPU:
    # N tokens, D hidden, V vocabulary entries.
    N, D, V = 512, 256, 4096
    E = jax.random.normal(k_e, (N, D), jnp.float32) * 0.05   # embeddings
    C = jax.random.normal(k_c, (V, D), jnp.float32) * 0.05   # classifier
    x = jax.random.randint(k_x, (N,), 0, V)                  # labels

    print(f"N={N} tokens, D={D} hidden, |V|={V} vocab")
    print(f"logit matrix would be N*V = {N*V:,} floats "
          f"({N*V*4/1e6:.1f} MB) — CCE never materializes it\n")

    # -- 1. the loss, once per registered backend --------------------------
    losses = {}
    for be in backends.all_backends():
        val = cross_entropy(E, C, x, impl=be.name, reduction="mean")
        losses[be.name] = float(val)
        print(f"  loss[{be.name:8s}] = {losses[be.name]:.6f}   "
              f"memory {be.memory_class}")
    for name, val in losses.items():
        assert abs(val - losses["dense"]) < 1e-4, name
    print("  all registered backends agree.\n")

    # -- 2. gradients match too (incl. the Pallas kernel custom VJP) -------
    def loss_fn(impl):
        def f(E, C):
            return cross_entropy(E, C, x, impl=impl, reduction="mean")
        return f

    dE_ref, dC_ref = jax.grad(loss_fn("dense"), argnums=(0, 1))(E, C)
    dE_cce, dC_cce = jax.grad(loss_fn("cce"), argnums=(0, 1))(E, C)
    print(f"  max|dE_cce - dE_dense| = {jnp.abs(dE_cce - dE_ref).max():.2e}")
    print(f"  max|dC_cce - dC_dense| = {jnp.abs(dC_cce - dC_ref).max():.2e}")

    # -- 3. paper variants: filtering / Kahan / vocab sorting ---------------
    print("\n  paper variants (all produce the same loss):")
    variants = {
        "CCE (filtered, f32 accum)": CCEConfig(),
        "CCE-FullC (pretraining)": CCEConfig(filter_mode_c="full"),
        "CCE-Kahan": CCEConfig(accum="bf16_kahan"),
        "CCE + vocab sorting": CCEConfig(sort_vocab=True),
    }
    for name, cfg in variants.items():
        val = cross_entropy(E, C, x, impl="cce", cfg=cfg, reduction="mean")
        print(f"    {name:28s} loss = {float(val):.6f}")

    # -- 4. the same call takes any registry loss --------------------------
    print("\n  registry losses through the same entry point:")
    for loss_name in ("nll", "z_loss", "label_smoothing"):
        val = cross_entropy(E, C, x, loss=loss_name, reduction="mean")
        print(f"    loss={loss_name:16s} -> {float(val):.6f}")

    print("\nquickstart OK")


if __name__ == "__main__":
    main()
