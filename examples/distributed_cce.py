"""Distributed CCE: vocab-parallel + sequence-parallel loss on a real mesh.

The beyond-paper extension (DESIGN.md §3): the classifier C is sharded over
the ``model`` mesh axis and tokens over the ``data`` axis; the global
(lse, pick) combine costs two O(N) psums — no O(N·|V|) logits and no
all-gather of C. Since the backend-registry redesign, distribution is a
*property of the call*: the same ``cross_entropy`` entry point takes
``mesh=`` and routes whatever backend it resolved through the shard_map
combine — and because every ``repro.losses`` entry is a function of the
global (lse, pick[, sum_logits]), registry losses distribute too.

This example builds a small host mesh (8 CPU devices via XLA_FLAGS, set
BEFORE jax import), checks the sharded loss and gradients against the
single-device dense oracle — for plain NLL *and* a registry loss — and
prints the collective schedule actually lowered.

Run:  PYTHONPATH=src python examples/distributed_cce.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import cross_entropy                        # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"on {jax.default_backend()}")

    N, D, V = 256, 128, 2048            # V/4 = 512 rows per model shard
    key = jax.random.PRNGKey(0)
    k_e, k_c, k_x = jax.random.split(key, 3)
    E = jax.random.normal(k_e, (N, D), jnp.float32) * 0.05
    C = jax.random.normal(k_c, (V, D), jnp.float32) * 0.05
    x = jax.random.randint(k_x, (N,), 0, V)

    # place the operands the way the production train step does:
    #   E, x  sequence-sharded over data;  C vocab-sharded over model
    E_s = jax.device_put(E, NamedSharding(mesh, P("data", None)))
    C_s = jax.device_put(C, NamedSharding(mesh, P("model", None)))
    x_s = jax.device_put(x, NamedSharding(mesh, P("data")))

    @jax.jit
    def dist_loss(E, C, x):
        # the SAME entry point as single-device — just add mesh=
        return cross_entropy(E, C, x, impl="cce_jax", mesh=mesh,
                             vocab_axis="model", token_axes=("data",),
                             reduction="mean")

    loss_dist = dist_loss(E_s, C_s, x_s)
    loss_ref = cross_entropy(E, C, x, impl="dense", reduction="mean")
    print(f"\nvocab-parallel CCE loss : {float(loss_dist):.6f}")
    print(f"single-device dense ref : {float(loss_ref):.6f}")
    assert abs(float(loss_dist) - float(loss_ref)) < 1e-4

    # gradients flow through the two psums + local custom VJP
    g_dist = jax.jit(jax.grad(dist_loss, argnums=(0, 1)))(E_s, C_s, x_s)
    g_ref = jax.grad(
        lambda E, C: cross_entropy(E, C, x, impl="dense",
                                   reduction="mean"),
        argnums=(0, 1))(E, C)
    for name, a, b in (("dE", g_dist[0], g_ref[0]),
                       ("dC", g_dist[1], g_ref[1])):
        err = float(jnp.abs(jnp.asarray(a) - b).max())
        print(f"max|{name}_dist - {name}_ref| = {err:.2e}")
        assert err < 1e-4, name

    # registry losses distribute through the same call: label smoothing's
    # third (sum_logits) output is one extra O(N) psum.
    ls_dist = jax.jit(lambda E, C, x: cross_entropy(
        E, C, x, loss="label_smoothing", impl="cce_jax", mesh=mesh,
        reduction="mean"))(E_s, C_s, x_s)
    ls_ref = cross_entropy(E, C, x, loss="label_smoothing", impl="dense",
                           reduction="mean")
    print(f"\nlabel_smoothing sharded : {float(ls_dist):.6f}  "
          f"(local dense ref {float(ls_ref):.6f})")
    assert abs(float(ls_dist) - float(ls_ref)) < 1e-4

    # show the wire cost: the only collectives are O(N) psums (+ the psums
    # of the shard_map transpose for dE/dC replication) — never O(N*V).
    hlo = jax.jit(dist_loss).lower(E_s, C_s, x_s).compile().as_text()
    colls = {}
    for line in hlo.splitlines():
        ls = line.strip()
        for kind in ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all"):
            if ls.startswith(kind) or f" {kind}(" in ls:
                colls[kind] = colls.get(kind, 0) + 1
    print(f"\ncollectives in the lowered forward: {colls or 'none'}")
    print(f"O(N*V) logit matrix would be {N*V*4/1e6:.1f} MB; "
          f"wire traffic here is O(N) = {N*4/1e3:.1f} KB per psum")
    print("\ndistributed_cce OK")


if __name__ == "__main__":
    main()
