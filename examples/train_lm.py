"""End-to-end driver: train a ~100M-param LLaMA-family LM with the CCE head.

This is deliverable (b)'s "train ~100M model for a few hundred steps" —
the full production stack on whatever devices are present: config system,
synthetic data pipeline, AdamW + warmup-cosine, gradient-accumulation
microbatching, checkpoint/restart (kill -TERM mid-run and re-launch to see
it resume), and the CCE loss head.

Run:     PYTHONPATH=src python examples/train_lm.py
Faster:  PYTHONPATH=src python examples/train_lm.py --steps 50 --tiny
Resume:  re-run the same command; it restores from --ckpt automatically.

The training loss is any entry of the ``repro.losses`` registry — all of
them ride the CCE (lse, pick[, sum]) primitive through the one
``repro.core.cross_entropy`` head, so none re-introduce the N×V logit
matrix; ``--loss-impl`` picks the ``repro.backends`` realization
(capability-checked against the chosen loss):

  z-loss (PaLM-style logit-norm regularizer):
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 50 \\
        --loss z_loss --loss-kwargs '{"z_weight": 1e-4}'
  label smoothing (exercises the kernel's third sum-logits output):
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 50 \\
        --loss label_smoothing --loss-kwargs '{"eps": 0.1}'
"""

import argparse
import dataclasses

from repro import backends
from repro.configs.base import ModelConfig, TrainConfig
from repro.losses import LossConfig, list_losses
from repro.train import Trainer


def model_100m(vocab_size: int = 32000) -> ModelConfig:
    """~100M params: 12L, d=768, 12H — GPT-2-small-shaped LLaMA blocks."""
    return ModelConfig(
        name="llama-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, d_ff=2048, vocab_size=vocab_size,
        mlp_activation="silu", dtype="float32", loss_impl="cce_jax",
        remat="block")


def model_tiny() -> ModelConfig:
    return dataclasses.replace(
        model_100m(vocab_size=2048), num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=512, name="llama-tiny")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    ap.add_argument("--tiny", action="store_true",
                    help="4L/256d model for a fast smoke run")
    ap.add_argument("--loss", default="nll",
                    help=f"repro.losses registry entry; one of "
                         f"{list_losses()}")
    ap.add_argument("--loss-kwargs", default="{}",
                    help='JSON hyper-parameters for --loss')
    ap.add_argument("--loss-impl", default=None,
                    choices=["auto"] + backends.list_backends(),
                    help="repro.backends entry for the loss head")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    if args.loss_impl:
        cfg = dataclasses.replace(cfg, loss_impl=args.loss_impl)
    print(f"model: {cfg.name}  params ~= {cfg.param_count()/1e6:.0f}M  "
          f"|V|={cfg.vocab_size}  loss_impl={cfg.loss_impl}  "
          f"loss={args.loss}")

    loss_cfg = LossConfig.from_json(args.loss, args.loss_kwargs)
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        microbatch=args.microbatch, checkpoint_every=50,
        grad_clip=1.0, seed=0,
        loss=loss_cfg.name, loss_kwargs=loss_cfg.kwargs)

    tr = Trainer(cfg, tcfg, checkpoint_dir=args.ckpt, seq_len=args.seq,
                 global_batch=args.batch)
    tr.install_signal_handlers()   # SIGTERM => checkpoint-and-exit
    if tr.step:
        print(f"resumed from checkpoint at step {tr.step}")

    history = tr.run(num_steps=args.steps, log_every=10)
    tr.save()

    if len(history) >= 2:
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"\nloss: {first:.4f} -> {last:.4f} over "
              f"{history[-1]['step']} steps "
              f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
