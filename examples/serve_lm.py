"""Serve a small LM through the continuous-batching engine.

Demonstrates the inference side of the framework:

  * slot-based continuous batching — requests with ragged prompt lengths
    share the batch, a mid-flight request joins as soon as a slot frees
    up, and each row decodes on its own timeline (per-row ``cache_index``);
  * chunked prefill (``--prefill-chunk``) — prompts are ingested several
    tokens per fused prefill+decode step, cutting TTFT without changing a
    single output token;
  * device-side sampling with *per-request* parameters (row 0 greedy next
    to row 1 at temperature 0.8 / top-p 0.9), one host sync per step;
  * CCE-backed scoring: ranking candidate completions by
    ``log p(completion | prompt)`` through
    ``cross_entropy(..., loss="seq_logprob")`` — the paper's primitive at
    inference, no (B, S, V) logits.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models import transformer as T
from repro.serve import Engine, SamplingParams, scoring


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b",
                    help="any assigned arch id; the reduced config is used")
    ap.add_argument("--batch", type=int, default=4,
                    help="engine slots (concurrent rows)")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens ingested per step during prefill "
                         "(1 = one-token teacher forcing)")
    args = ap.parse_args()

    cfg = configs.get_reduced_config(args.arch)
    print(f"arch={cfg.name} (reduced): {cfg.num_layers}L d={cfg.d_model} "
          f"|V|={cfg.vocab_size} pattern={cfg.layer_pattern}")

    params = T.init_lm(jax.random.PRNGKey(0), cfg)

    # more requests than slots, with ragged prompt lengths and mixed
    # sampling policies: the queue drains as rows finish
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (3, 7, 5, 11, 4, 9)]

    enc_out = None
    batch = args.batch
    if cfg.is_encdec:   # seamless: condition decoding on stub frame embeds;
        # slot i reads encoder row i, so the engine gets exactly one slot
        # per request and enc_out one row per slot
        prompts = prompts[: args.batch]
        batch = len(prompts)
        enc_out = jax.random.normal(
            jax.random.PRNGKey(1), (batch, 16, cfg.d_model),
            dtype=cfg.dtype) * 0.02
    engine = Engine(cfg, params, max_len=128, batch_size=batch,
                    prefill_chunk=args.prefill_chunk, enc_out=enc_out)
    policies = [SamplingParams(),                                  # greedy
                SamplingParams(temperature=0.8, top_p=0.9, seed=1),
                SamplingParams(temperature=1.0, top_k=40, seed=2)]

    t0 = time.time()
    rids = [engine.submit(p, max_new_tokens=args.max_new,
                          sampling=policies[i % len(policies)])
            for i, p in enumerate(prompts)]
    comps = engine.run()
    dt = time.time() - t0

    total_new = sum(len(comps[r].tokens) for r in rids)
    for i, r in enumerate(rids):
        c = comps[r]
        ttft = (c.first_token_time - c.submit_time) * 1e3 \
            if c.first_token_time else float("nan")
        print(f"  req[{i}] prompt_len={len(c.prompt):2d} "
              f"ttft={ttft:6.1f}ms -> {len(c.tokens)} tokens: "
              f"{c.tokens[:8]}{'...' if len(c.tokens) > 8 else ''}")
    print(f"\n{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, {args.batch} slots, "
          f"{len(prompts)} requests on {jax.default_backend()})")

    # CCE-backed scoring: rerank the model's own continuation against two
    # random candidates (decoder-only; encdec scoring is a ROADMAP item)
    if not cfg.is_encdec and comps[rids[0]].tokens:
        prompt = prompts[0]
        candidates = [
            comps[rids[0]].tokens[:4],
            [int(t) for t in rng.integers(0, cfg.vocab_size, size=4)],
            [int(t) for t in rng.integers(0, cfg.vocab_size, size=4)]]
        order, scores = scoring.rank(params, cfg, prompt, candidates)
        print("\nscoring (log p per token, CCE-backed — no (B,S,V) "
              "logits):")
        for r, i in enumerate(order):
            print(f"  #{r + 1} score={scores[i]:+.3f} "
                  f"candidate {candidates[i]}")


if __name__ == "__main__":
    main()
