"""Serve a small LM with batched requests through the decode engine.

Demonstrates the inference side of the framework: ``init_cache`` +
``serve_step`` (the function the decode_32k / long_500k dry-run cells
lower) wrapped in the continuous-batching-lite ``Engine``. Requests with
different prompt lengths share one batch; rows still in their prompt are
teacher-forced while finished rows generate.

Also shows the paper's §3.2 point: inference needs the vocab distribution
for ONE position per sequence, so serving memory is O(B·V), independent of
sequence length — CCE is a training-time fix.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models import transformer as T
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b",
                    help="any assigned arch id; the reduced config is used")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_reduced_config(args.arch)
    print(f"arch={cfg.name} (reduced): {cfg.num_layers}L d={cfg.d_model} "
          f"|V|={cfg.vocab_size} pattern={cfg.layer_pattern}")

    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_len=128, batch_size=args.batch)

    # batched requests with ragged prompt lengths
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (3, 7, 5, 11)][: args.batch]

    enc_out = None
    if cfg.is_encdec:   # seamless: condition decoding on stub frame embeds
        enc_out = jax.random.normal(
            jax.random.PRNGKey(1), (len(prompts), 16, cfg.d_model),
            dtype=cfg.dtype) * 0.02

    t0 = time.time()
    outs = engine.generate(prompts, max_new_tokens=args.max_new,
                           enc_out=enc_out)
    dt = time.time() - t0

    total_new = sum(len(o) for o in outs)
    for i, (p, o) in enumerate(zip(prompts, outs)):
        print(f"  req[{i}] prompt_len={len(p):2d} -> "
              f"{len(o)} tokens: {o[:10]}{'...' if len(o) > 10 else ''}")
    print(f"\n{total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s batched greedy decode on "
          f"{jax.default_backend()})")

    # sanity: deterministic greedy decode reproduces itself
    outs2 = engine.generate(prompts, max_new_tokens=args.max_new,
                            enc_out=enc_out)
    assert outs == outs2, "greedy decode must be deterministic"
    print("determinism check OK")


if __name__ == "__main__":
    main()
