"""Training loop: jit'd step with gradient accumulation, checkpoint/resume,
preemption handling, and optional gradient-compression for the DP all-reduce.

The distributed configuration (mesh, param/activation shardings, vocab-
parallel CCE head) is injected by the launcher (repro.launch.train); this
module is mesh-agnostic and also runs single-device (examples, tests).
"""

from __future__ import annotations

import signal
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.kernels.ref import IGNORE_INDEX
from repro.models import transformer as T
from repro.obs import metrics as M
from repro.obs import trace as Tr
from repro.optim import adamw
from repro.train.checkpoint import CheckpointManager


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, loss_fn=None,
                    loss_impl=None, mesh=None, vocab_axis: str = "model",
                    token_axes=("data",), cce_cfg=None):
    """Returns step(params, opt_state, batch, step_idx) -> (params, opt,
    metrics). Gradient accumulation: batch is split into microbatches along
    the batch axis and grads are averaged with a lax.scan (the scheduling
    substrate pipeline parallelism would reuse).

    mesh/vocab_axis/token_axes: forwarded to the ``cross_entropy`` head —
    the production launcher passes its mesh so the loss runs through the
    vocab-parallel combine with whatever backend ``loss_impl`` (or
    ``cfg.loss_impl``) resolves to. ``cce_cfg`` carries the kernel-level
    CCEConfig knobs (sort_vocab, filter modes, accumulator) to the
    resolved backend."""

    def loss_of(params, batch):
        return T.train_loss(params, cfg, batch, loss_fn=loss_fn,
                            loss_impl=loss_impl,
                            loss=tcfg.loss, loss_kwargs=tcfg.loss_options(),
                            mesh=mesh, vocab_axis=vocab_axis,
                            token_axes=token_axes, cce_cfg=cce_cfg)

    def step(params, opt_state, batch, step_idx):
        b = batch["labels"].shape[0]
        micro = min(tcfg.microbatch or b, b)   # clamp: micro can't exceed b
        assert b % micro == 0, (b, micro)
        n_micro = b // micro

        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, micro) + x.shape[1:]), batch)

            def acc_step(carry, one):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_of)(params, one)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, g_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zeros), mb)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        if tcfg.grad_allreduce_dtype:
            # gradient compression for the cross-pod all-reduce: cast to the
            # wire dtype; XLA reduces in that dtype and the optimizer
            # accumulates back in f32 master statistics.
            wire = jnp.dtype(tcfg.grad_allreduce_dtype)
            grads = jax.tree.map(lambda g: g.astype(wire), grads)

        lr = adamw.warmup_cosine(
            step_idx, base_lr=tcfg.learning_rate,
            warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps)
        params, opt_state, om = adamw.adamw_update(
            grads, opt_state, params, lr=lr, b1=tcfg.beta1, b2=tcfg.beta2,
            eps=tcfg.eps, weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip)
        # n_tokens: the one extra scalar the observability layer rides on —
        # valid (non-ignored) label count of this step, computed inside the
        # already-compiled step so tokens/s accounting stays device-side
        # and costs no extra sync (the Trainer accumulates it across steps
        # and materializes the sum only at log boundaries).
        n_tok = jnp.sum(batch["labels"] != IGNORE_INDEX).astype(jnp.float32)
        metrics = {"loss": loss, "lr": lr, "n_tokens": n_tok, **om}
        return params, opt_state, metrics

    return step


class Trainer:
    """Single-process training driver with checkpoint/restart.

    Preemption-safe: SIGTERM/SIGINT triggers a final checkpoint before exit
    (install_signal_handlers). Restart resumes params, optimizer and the
    data position (= step, since batches are pure functions of step).
    """

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, *,
                 data: SyntheticLM | None = None, checkpoint_dir=None,
                 seq_len: int = 512, global_batch: int = 8, loss_fn=None,
                 loss_impl=None, mesh=None, vocab_axis: str = "model",
                 token_axes=("data",), cce_cfg=None, jit: bool = True,
                 metrics: M.Registry | None = None,
                 tracer: Tr.Tracer | None = None):
        self.cfg, self.tcfg = cfg, tcfg
        # observability (repro.obs): gauges/counters updated and one
        # structured record emitted per log boundary — never per step, so
        # enabling metrics adds no host syncs beyond the float() pulls
        # the log line already performs.
        self.metrics = metrics if metrics is not None else M.NULL
        self.tracer = tracer if tracer is not None else Tr.NULL
        self.data = data or SyntheticLM(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=global_batch, seed=tcfg.seed))
        self.ckpt = (CheckpointManager(checkpoint_dir, tcfg.keep_checkpoints)
                     if checkpoint_dir else None)
        # dispatch arguments pass straight through to make_train_step: a
        # Trainer can select any backend / the vocab-parallel head, not
        # just the cfg defaults
        step_fn = make_train_step(cfg, tcfg, loss_fn=loss_fn,
                                  loss_impl=loss_impl, mesh=mesh,
                                  vocab_axis=vocab_axis,
                                  token_axes=token_axes, cce_cfg=cce_cfg)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1)) if jit \
            else step_fn
        self._preempted = False

        key = jax.random.PRNGKey(tcfg.seed)
        self.params = T.init_lm(key, cfg)
        self.opt_state = adamw.adamw_init(self.params)
        self.step = 0
        self.history: list[dict] = []
        self._tokens_total = 0.0
        if self.ckpt is not None:
            self._try_resume()

    def _try_resume(self):
        tree, step, extra = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state})
        if tree is not None:
            self.params, self.opt_state = tree["params"], tree["opt"]
            self.step = step
            return True
        return False

    def install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def save(self):
        if self.ckpt is not None:
            self.ckpt.save(self.step,
                           {"params": self.params, "opt": self.opt_state},
                           extra={"time": time.time()})

    def run(self, num_steps: int | None = None, log_every: int = 10,
            log_fn=print):
        """Drive the training loop, emitting one *structured* step record
        per log boundary: ``{step, loss, lr, grad_norm, n_tokens,
        step_wall_s, tokens_per_s, tokens_total}`` — appended to
        ``self.history``, mirrored into the metrics registry (gauges +
        counters + a step-wall histogram), written to the tracer sink as
        a ``train_step`` event, and rendered through ``log_fn``.

        Token accounting is device-side: each step's valid-label count is
        one scalar in the jitted step output, accumulated on device and
        materialized only here — logging adds no per-step host syncs.
        """
        total = num_steps or self.tcfg.total_steps
        tok_acc = jnp.zeros((), jnp.float32)    # device-side window sum
        tokens_total = self._tokens_total
        win_t0, win_step0 = time.time(), self.step
        while self.step < total and not self._preempted:
            batch = self.data.batch_at(self.step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, self.step)
            tok_acc = tok_acc + metrics["n_tokens"]
            self.step += 1
            if self.step % log_every == 0 or self.step == total:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step
                now = time.time()
                wall = now - win_t0
                n_win = self.step - win_step0
                win_toks = float(tok_acc)
                tokens_total += win_toks
                m["step_wall_s"] = wall / max(n_win, 1)
                m["tokens_per_s"] = win_toks / wall if wall > 0 else 0.0
                m["tokens_total"] = tokens_total
                self.history.append(m)
                self._record(m, n_win, win_toks)
                if log_fn:
                    log_fn(f"step {m['step']:5d} loss {m['loss']:.4f} "
                           f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.3f} "
                           f"{m['tokens_per_s']:.0f} tok/s")
                tok_acc = jnp.zeros((), jnp.float32)
                win_t0, win_step0 = now, self.step
            if (self.ckpt is not None and self.tcfg.checkpoint_every
                    and self.step % self.tcfg.checkpoint_every == 0):
                self.save()
        self._tokens_total = tokens_total
        if self._preempted:
            self.save()   # preemption-safe final checkpoint
        return self.history

    def _record(self, m: dict, n_win: int, win_toks: float) -> None:
        """Mirror one structured step record into the obs layer."""
        mets = self.metrics
        if mets.enabled:
            for k in ("loss", "lr", "grad_norm", "tokens_per_s"):
                mets.gauge(f"train_{k}").set(m[k])
            mets.counter("train_steps_total").inc(n_win)
            mets.counter("train_tokens_total").inc(win_toks)
            mets.histogram("train_step_wall_seconds").observe(
                m["step_wall_s"])
        self.tracer.event("train_step", **m)
