"""Checkpoint manager: atomic, keep-k, resumable, elastic.

Fault-tolerance contract (DESIGN.md §5):
  * atomic publish — arrays are written to ``<dir>/tmp.<step>`` and renamed,
    so a crash mid-write never corrupts the latest checkpoint;
  * manifest with per-array checksums — a torn/bit-rotted restore is
    detected, and the manager falls back to the previous checkpoint;
  * keep-last-k garbage collection;
  * the data-pipeline state is one integer (step) because batches are pure
    functions of the step index (repro.data.synthetic);
  * elastic restarts: arrays are stored *unsharded* (gathered); ``restore``
    device_puts them under whatever shardings the new mesh dictates, so the
    same checkpoint restores on a different device count.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import zlib

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    dtypes = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V":
            # ml_dtypes (bfloat16 etc.) round-trip .npz as raw void bytes;
            # store the bit pattern and record the true dtype in the
            # manifest so restore can view it back.
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        items[key] = arr
    return items, dtypes, treedef


def _restore_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """Undo the void-dtype bit-pattern storage of ``_flatten``."""
    if np.dtype(arr.dtype).name != dtype_str:
        import ml_dtypes
        try:
            return arr.view(np.dtype(dtype_str))
        except TypeError:
            return arr.view(getattr(ml_dtypes, dtype_str))
    return arr


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: dict | None = None):
        items, dtypes, _ = _flatten(tree)
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:012d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "arrays": {}, "extra": extra or {}}
        np.savez(os.path.join(tmp, "arrays.npz"), **items)
        with open(os.path.join(tmp, "arrays.npz"), "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["crc32"] = crc
        manifest["arrays"] = {k: [list(v.shape), dtypes[k]]
                              for k, v in items.items()}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _verify(self, path):
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            crc = zlib.crc32(f.read())
        if crc != manifest["crc32"]:
            raise IOError(f"checksum mismatch in {path}")
        return manifest

    def restore(self, template, step: int | None = None, shardings=None):
        """Restore into the structure of ``template``.

        Tries the newest checkpoint first; on corruption falls back to older
        ones (node-failure tolerance). ``shardings``: optional pytree (same
        structure) of jax.sharding.Sharding for elastic re-sharding.
        Returns (tree, step, extra) or (None, None, None).
        """
        steps = self.all_steps() if step is None else [step]
        for s in reversed(steps):
            path = os.path.join(self.dir, f"step_{s:012d}")
            try:
                manifest = self._verify(path)
            except Exception:
                continue
            data = np.load(os.path.join(path, "arrays.npz"))
            keys, _, treedef = _flatten(template)
            flat = []
            shard_flat = (jax.tree.leaves(shardings)
                          if shardings is not None else None)
            for i, key in enumerate(keys):
                arr = _restore_dtype(data[key],
                                     manifest["arrays"][key][1])
                if shard_flat is not None:
                    arr = jax.device_put(arr, shard_flat[i])
                flat.append(arr)
            tree = jax.tree_util.tree_unflatten(treedef, flat)
            return tree, s, manifest.get("extra", {})
        return None, None, None
