"""Training loop + checkpoint manager."""
from repro.train.checkpoint import CheckpointManager  # noqa: F401
from repro.train.trainer import Trainer, make_train_step  # noqa: F401
