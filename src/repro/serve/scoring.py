"""CCE-backed candidate scoring: the paper's training-time trick as an
inference feature.

Scoring/reranking B candidate completions of length S against one prompt
is the inference workload where the (N, V) logit matrix *reappears*: a
dense scorer computes ``log_softmax(E @ C.T)`` over every completion
position — O(B·S·V) memory, the exact shape CCE was built to kill at
training time. Here the model runs teacher-forced to get embeddings E and
the per-token/sequence log-probabilities lower through
``cross_entropy(E, C, labels, loss="seq_logprob", impl=...)`` — the CCE
primitive's (lse, pick) outputs — so scoring costs O(B·S·D + V·D) and the
jitted HLO contains no (B, S, V) buffer (gated by
``benchmarks/serve_throughput.py`` and ``tests/test_serve.py`` via
``analysis/hlo.array_shape_census``). Dispatch goes through the
:mod:`repro.backends` registry, so ``mesh=`` runs the same scorer under
the vocab-parallel combine.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.kernels.ref import IGNORE_INDEX
from repro.models import transformer as T


def build_scoring_batch(prompt, completions, pad_to: int | None = None):
    """Teacher-forcing batch for ``log p(completion | prompt)``.

    Row b is ``prompt + completions[b]`` (zero-padded); ``labels[b, i]`` is
    the token row b must predict at position i — completion tokens over
    positions ``len(prompt)-1 .. len(prompt)+len(c)-2``, IGNORE_INDEX
    everywhere else (prompt positions score nothing, padding scores
    nothing). Returns (tokens (B, S) i32, labels (B, S) i32) numpy arrays.
    """
    if not prompt:
        raise ValueError("empty prompt")
    if not completions or any(not c for c in completions):
        raise ValueError("completions must be non-empty token lists")
    lp = len(prompt)
    s = max(lp + len(c) for c in completions)
    if pad_to is not None:
        if pad_to < s:
            raise ValueError(f"pad_to={pad_to} shorter than the longest "
                             f"prompt+completion ({s})")
        s = pad_to
    b = len(completions)
    tokens = np.zeros((b, s), np.int32)
    labels = np.full((b, s), IGNORE_INDEX, np.int32)
    for i, c in enumerate(completions):
        row = list(prompt) + list(c)
        tokens[i, :len(row)] = row
        labels[i, lp - 1:lp - 1 + len(c)] = c
    return tokens, labels


def score_fn(cfg, *, normalize: str = "sum", impl: str | None = None,
             per_token: bool = False, mesh=None, vocab_axis: str = "model",
             token_axes=("data",), cce_cfg=None):
    """The pure scorer ``(params, tokens, labels) -> scores`` — jit it, lower
    it for HLO analysis, or call it under a mesh.

    normalize: "sum" (raw sequence log-prob) | "tokens" (length-normalized,
        the rescoring convention).
    per_token: return (B, S) per-token log-probs (0 at ignored positions)
        instead of (B,) sequence scores.
    impl/mesh/...: forwarded to :func:`repro.core.cross_entropy` — the
        backend registry decides the realization, exactly as in training.
    """
    from repro.core import cross_entropy  # lazy: keeps serve import light
    from repro.losses import get_loss

    if cfg.is_encdec:
        # lm_hidden(enc_out=None) would silently turn every cross-attention
        # block into self-attention; encoder-conditioned scoring needs the
        # encoder inputs threaded through (ROADMAP: scoring-server batching)
        raise NotImplementedError(
            "scoring does not support encoder-decoder configs yet: it "
            "would need the encoder inputs to condition on")
    loss = (get_loss("nll") if per_token
            else get_loss("seq_logprob", normalize=normalize))

    def fn(params, tokens, labels):
        hidden, _, _ = T.lm_hidden(params, cfg, {"tokens": tokens})
        C = T.classifier_matrix(params, cfg)
        E = hidden.astype(C.dtype)
        out = cross_entropy(
            E, C, labels, loss=loss, impl=impl or cfg.loss_impl,
            softcap=cfg.logit_softcap, reduction="none", mesh=mesh,
            vocab_axis=vocab_axis, token_axes=token_axes, cfg=cce_cfg)
        # nll -> log-prob for the per-token view; ignored positions are 0
        return -out if per_token else out

    return fn


@functools.lru_cache(maxsize=32)
def _jitted_scorer(cfg, normalize, impl, per_token, mesh, vocab_axis,
                   token_axes, cce_cfg):
    # EVERY argument that alters the lowering must be part of this cache
    # key: a key that omitted mesh/vocab_axis/token_axes would silently
    # hand back a scorer compiled for a different (or no) mesh.
    return jax.jit(score_fn(cfg, normalize=normalize, impl=impl,
                            per_token=per_token, mesh=mesh,
                            vocab_axis=vocab_axis, token_axes=token_axes,
                            cce_cfg=cce_cfg))


def score(params, cfg, prompt, completions, *, normalize: str = "sum",
          impl: str | None = None, pad_to: int | None = None,
          mesh=None, vocab_axis: str = "model", token_axes=("data",),
          cce_cfg=None):
    """log p(completion | prompt) for each candidate, CCE-backed.

    Returns a list of floats (one per completion), computed without ever
    materializing the (B, S, V) logit matrix. ``pad_to`` pads the batch to
    a fixed length so repeated calls reuse one jit trace. ``mesh`` (with
    ``vocab_axis``/``token_axes``) runs the scorer under the
    vocab-parallel combine, exactly as in training.
    """
    tokens, labels = build_scoring_batch(prompt, completions, pad_to=pad_to)
    fn = _jitted_scorer(cfg, normalize, impl or cfg.loss_impl, False,
                        mesh, vocab_axis, tuple(token_axes), cce_cfg)
    return [float(v) for v in fn(params, tokens, labels)]


def token_logprobs(params, cfg, prompt, completions, *,
                   impl: str | None = None, pad_to: int | None = None,
                   mesh=None, vocab_axis: str = "model",
                   token_axes=("data",), cce_cfg=None):
    """Per-token log-probs: list (per candidate) of lists (per completion
    token), same CCE lowering as :func:`score`."""
    tokens, labels = build_scoring_batch(prompt, completions, pad_to=pad_to)
    fn = _jitted_scorer(cfg, "sum", impl or cfg.loss_impl, True,
                        mesh, vocab_axis, tuple(token_axes), cce_cfg)
    lp = np.asarray(fn(params, tokens, labels))
    out = []
    for i, c in enumerate(completions):
        start = len(prompt) - 1
        out.append([float(v) for v in lp[i, start:start + len(c)]])
    return out


def rank(params, cfg, prompt, completions, *, normalize: str = "tokens",
         impl: str | None = None, pad_to: int | None = None,
         mesh=None, vocab_axis: str = "model", token_axes=("data",),
         cce_cfg=None):
    """Candidate indices best-first by (length-normalized) log-prob."""
    s = score(params, cfg, prompt, completions, normalize=normalize,
              impl=impl, pad_to=pad_to, mesh=mesh, vocab_axis=vocab_axis,
              token_axes=token_axes, cce_cfg=cce_cfg)
    return sorted(range(len(s)), key=lambda i: -s[i]), s
