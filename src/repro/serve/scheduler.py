"""Slot-based continuous-batching scheduler.

The engine's batch is ``B`` *slots*. A request occupies one slot from
admission to completion; the moment a row finishes, the host retires it and
the slot (and its KV-cache rows) is recycled for the next queued request —
rows join and leave mid-flight, nothing waits for the slowest row.

Split of responsibilities:

  * all *per-token* state lives on device in one pytree of (B, ...) arrays
    (``init_state``) and is advanced by the pure, jit-friendly
    :func:`advance_slots` — per-row prompt teacher-forcing, sampling,
    EOS/length/capacity stopping, per-row ``cache_index`` bookkeeping. No
    Python branches over rows, so the engine's whole decode step is one jit
    and the host syncs once per step regardless of batch size;
  * the *request* lifecycle (queue, slot assignment, retirement) lives on
    host in :class:`Scheduler`, which only touches the device on admission
    and retirement — and always with batch-shaped masked updates, so those
    jits compile once per engine shape, not once per admission count.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.obs import metrics as M
from repro.obs import trace as Tr
from repro.serve import sampling as S

NO_EOS = -1


@dataclasses.dataclass
class Request:
    """One generation request (host-side)."""
    prompt: List[int]
    max_new_tokens: int = 16
    sampling: S.SamplingParams = S.GREEDY
    eos_token: Optional[int] = None
    slot: Optional[int] = None          # pin to one slot (enc_out rows)
    rid: int = -1                       # assigned by the scheduler
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    admit_step: int = -1                # engine step_count at admission
    # page-aligned prompt prefix already resident in the KV pool at
    # admission (copy-free reuse): prefill starts at this position
    reused_tokens: int = 0


@dataclasses.dataclass
class Completion:
    """A finished request as handed back by ``Engine.step``."""
    rid: int
    tokens: List[int]
    prompt: List[int]
    finish_reason: str                  # "eos" | "length" | "cache_full"
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: float = 0.0
    # per generated token: log-probability under the distribution it was
    # drawn from (raw softmax for greedy rows, renormalized kept-set
    # distribution for filtered rows — DESIGN.md §10)
    logprobs: List[float] = dataclasses.field(default_factory=list)


def init_state(batch_size: int, max_prompt_len: int, max_new_cap: int,
               spec_k: int = 0):
    """Fresh slot-state pytree: everything (B, ...), everything on device.

    spec_k > 0 (speculative decoding, DESIGN.md §12) adds the draft-loop
    state: ``spec_src``/``spec_n`` record the window each row actually
    consumed last step (the draft model's catch-up input), and
    ``spec_hist``/``spec_drafted``/``spec_emitted`` accumulate acceptance
    telemetry device-side so the engine's single per-step sync can carry
    it to the metrics registry with zero extra transfers. The last three
    are global (not per-row) and — like ``t`` — must survive admission,
    so the scheduler template excludes them.
    """
    b = batch_size
    state = {
        "tok": jnp.zeros((b, 1), jnp.int32),
        "cache_index": jnp.zeros((b,), jnp.int32),
        "active": jnp.zeros((b,), bool),
        "done": jnp.zeros((b,), bool),
        "prompt_buf": jnp.zeros((b, max_prompt_len), jnp.int32),
        "prompt_len": jnp.ones((b,), jnp.int32),
        "out_buf": jnp.zeros((b, max_new_cap), jnp.int32),
        "logprob_buf": jnp.zeros((b, max_new_cap), jnp.float32),
        "n_out": jnp.zeros((b,), jnp.int32),
        "max_new": jnp.ones((b,), jnp.int32),
        "eos": jnp.full((b,), NO_EOS, jnp.int32),
        "temperature": jnp.zeros((b,), jnp.float32),
        "top_k": jnp.zeros((b,), jnp.int32),
        "top_p": jnp.ones((b,), jnp.float32),
        "rng": jnp.stack([jax.random.PRNGKey(0)] * b),
        # sticky per-row finish reason: 0 none, 1 eos, 2 length, 3 cache
        "finish": jnp.zeros((b,), jnp.int32),
        # device step index (value of "t") at which the row's first token
        # was generated; -1 until then. The host converts it to wall time
        # at retirement, so TTFT stays honest under --sync-every > 1.
        "gen_step": jnp.full((b,), -1, jnp.int32),
        # global device step counter — one per advance_slots call, aligned
        # with the engine's host-side step_count. NOT per-row: admission
        # must never reset it (the scheduler template excludes it).
        "t": jnp.zeros((), jnp.int32),
    }
    if spec_k > 0:
        s = spec_k + 1
        # window of tokens this row consumed last spec round (catch-up
        # input for the draft model) and how many of them were committed
        state["spec_src"] = jnp.zeros((b, s), jnp.int32)
        state["spec_n"] = jnp.zeros((b,), jnp.int32)
        # global acceptance telemetry: spec_hist[n] counts decode rounds
        # that emitted n tokens (n in 0..spec_k+1); drafted/emitted are
        # running token totals. Scalar/global leaves, template-excluded.
        state["spec_hist"] = jnp.zeros((s + 1,), jnp.int32)
        state["spec_drafted"] = jnp.zeros((), jnp.int32)
        state["spec_emitted"] = jnp.zeros((), jnp.int32)
    return state


def sample_keys(state, n_tok=None, chunk: int = 1):
    """This step's per-row sampling key + the advanced PRNG carry.

    Each row's PRNG stream advances by exactly ``n_tok`` splits and the
    sample key is the one the ``n_tok``-th one-token step would have
    used, so a chunked prefill replays the identical token sequence,
    greedy or sampled. Factored out of :func:`advance_slots` because the
    fused decode path needs the key *before* the forward (it goes into
    the projection->sample kernel), while the dense path draws after.
    """
    b = state["rng"].shape[0]
    if n_tok is None:
        n_tok = jnp.ones((b,), jnp.int32)
    if chunk == 1:
        rng_next = jax.vmap(lambda k: jax.random.split(k, 2))(state["rng"])
        return rng_next[:, 1], rng_next[:, 0]
    keys, carries = sample_keys_all(state, chunk)
    sel = jnp.clip(n_tok - 1, 0, chunk - 1)
    sample_key = jnp.take_along_axis(
        keys, sel[:, None, None], axis=1)[:, 0]
    rng_carry = jnp.take_along_axis(
        carries, jnp.clip(n_tok, 0, chunk)[:, None, None],
        axis=1)[:, 0]
    return sample_key, rng_carry


def sample_keys_all(state, chunk: int):
    """All ``chunk`` per-position sample keys plus every PRNG carry.

    ``keys[:, j]`` is the key the ``(j+1)``-th one-token step would have
    used and ``carries[:, n]`` is the stream after ``n`` splits, so a
    speculative round that consumes ``n`` tokens picks ``carries[:, n]``
    as its carry and each verified position ``j`` samples with
    ``keys[:, j]`` — the same discipline chunked prefill established.
    Returns ``(keys (B, chunk, 2), carries (B, chunk+1, 2))``.
    """
    carry, keys, carries = state["rng"], [], [state["rng"]]
    for _ in range(chunk):          # static unroll: chunk is a jit const
        nxt = jax.vmap(lambda k: jax.random.split(k, 2))(carry)
        keys.append(nxt[:, 1])
        carry = nxt[:, 0]
        carries.append(carry)
    return jnp.stack(keys, 1), jnp.stack(carries, 1)


def advance_slots(state, logits=None, *, max_len: int, n_tok=None,
                  chunk: int = 1, fused=None):
    """One slot-state transition from this step's model output.

    Pure function — the engine fuses it with ``serve_step``/
    ``serve_prefill`` into a single jit. Per row: sample a token, decide
    whether it is teacher-forced prompt or generated output, record it
    (token + logprob), update EOS/length/capacity stop flags, and advance
    ``cache_index`` only for rows still running.

    Two input modes:

    * dense — ``logits`` is this step's (B, V) matrix; the sampler runs
      here (:func:`sampling.sample_tokens`).
    * fused — ``fused=(sampled, logprob, rng_carry)`` as produced by the
      projection->sample kernel plus :func:`sample_keys`; no (B, V)
      array ever reaches this function.

    n_tok (B,): tokens each row consumed this step (chunked prefill);
    defaults to one. ``chunk`` is the static upper bound of ``n_tok``
    (see :func:`sample_keys` for the replay guarantee).
    """
    b, m = state["out_buf"].shape
    rows = jnp.arange(b)
    live = state["active"] & ~state["done"]
    if n_tok is None:
        n_tok = jnp.ones((b,), jnp.int32)

    if fused is None:
        sample_key, rng_carry = sample_keys(state, n_tok, chunk)
        sampled, logprob = S.sample_tokens(
            logits, sample_key, state["temperature"], state["top_k"],
            state["top_p"], return_logprob=True)
    else:
        sampled, logprob, rng_carry = fused
        sampled = sampled.astype(jnp.int32)

    cur_pos = state["cache_index"]
    nxt_pos = cur_pos + n_tok
    in_prompt = nxt_pos < state["prompt_len"]
    p_cap = state["prompt_buf"].shape[1]
    prompt_next = jnp.take_along_axis(
        state["prompt_buf"], jnp.clip(nxt_pos, 0, p_cap - 1)[:, None],
        axis=1)[:, 0]

    # the logits at the *last* prompt position predict the first completion
    # token, so a row generates exactly when its next input is past the
    # prompt
    gen = live & ~in_prompt
    slot = jnp.clip(state["n_out"], 0, m - 1)
    cur_val = state["out_buf"][rows, slot]
    out_buf = state["out_buf"].at[rows, slot].set(
        jnp.where(gen, sampled, cur_val))
    cur_lp = state["logprob_buf"][rows, slot]
    logprob_buf = state["logprob_buf"].at[rows, slot].set(
        jnp.where(gen, logprob, cur_lp))
    n_out = state["n_out"] + gen

    hit_eos = gen & (state["eos"] != NO_EOS) & (sampled == state["eos"])
    hit_len = gen & (n_out >= state["max_new"])
    # nxt_pos == max_len would write past the cache on the following step
    hit_cap = live & (nxt_pos >= max_len)
    done = state["done"] | hit_eos | hit_len | hit_cap

    advance = live & ~done
    next_tok = jnp.where(in_prompt, prompt_next, sampled)
    new_state = dict(
        state,
        tok=jnp.where(advance[:, None], next_tok[:, None], state["tok"]),
        cache_index=jnp.where(advance, nxt_pos, cur_pos),
        done=done,
        out_buf=out_buf,
        logprob_buf=logprob_buf,
        n_out=n_out,
        rng=rng_carry,
        finish=jnp.where(
            state["finish"] > 0, state["finish"],
            jnp.where(hit_eos, 1, jnp.where(hit_len, 2,
                      jnp.where(hit_cap, 3, 0)))),
        gen_step=jnp.where(gen & (state["gen_step"] < 0), state["t"],
                           state["gen_step"]),
        t=state["t"] + 1,
    )
    return new_state


_FINISH_REASONS = {1: "eos", 2: "length", 3: "cache_full"}


# Admission/retirement touch the device with *batch-shaped* updates only
# (a (B,) mask selects the affected rows): the compiled computation is
# independent of how many requests join or leave at once, so these jits
# compile exactly once per engine shape instead of once per distinct
# admission/retirement count.

@jax.jit
def _apply_admission(state, cache, mask, new):
    def sel(cur, n):
        m = mask.reshape((-1,) + (1,) * (cur.ndim - 1))
        return jnp.where(m, n, cur)
    state = dict(state)
    for k, v in new.items():
        state[k] = sel(state[k], v)
    return state, T.reset_cache_rows(cache, mask)


@jax.jit
def _apply_admission_paged(state, cache, mask, new, pt_rows):
    """Admission for the paged cache: same batch-shaped update, plus the
    admitted rows' logical->physical page tables (host-built, (B,
    n_logical) int32 with -1 padding) land in ``cache["pt"]``."""
    state, cache = _apply_admission(state, cache, mask, new)
    cache = dict(cache)
    cache["pt"] = jnp.where(mask[:, None], pt_rows, cache["pt"])
    return state, cache


@jax.jit
def _advance_rng(key, n):
    """Advance a PRNG key by ``n`` split-carries — the exact chain
    ``advance_slots`` applies once per consumed token. Admission uses it
    to pre-advance a row's stream past a reused prefix, so a request
    admitted onto shared pages samples the identical tokens it would have
    sampled after prefilling those positions itself."""
    return jax.lax.fori_loop(
        0, n, lambda _, k: jax.random.split(k, 2)[0], key)


@jax.jit
def _apply_retirement(state, mask):
    return dict(state, active=jnp.where(mask, False, state["active"]))


class Scheduler:
    """Host-side request lifecycle: admission queue + slot bookkeeping."""

    def __init__(self, batch_size: int, max_prompt_len: int,
                 max_new_cap: int, vocab_size: int,
                 metrics: M.Registry | None = None,
                 tracer: Tr.Tracer | None = None,
                 pool=None, decode_kernel: str = "dense",
                 spec_k: int = 0):
        self.batch_size = batch_size
        # which decode path feeds this scheduler ("fused" | "dense") —
        # only a metrics label, so the two paths separate in traces
        self.decode_kernel = decode_kernel
        # speculative draft length (0 = off) — sizes the spec_* state
        # fields and labels the latency histograms
        self.spec_k = spec_k
        self.max_prompt_len = max_prompt_len
        self.max_new_cap = max_new_cap
        self.vocab_size = vocab_size
        # optional repro.serve.kvpool.KVPool: admission gains a page-budget
        # gate (a request admits only if its whole worst-case page span is
        # reservable) and copy-free prefix reuse; retirement decrefs pages
        self.pool = pool
        # host-only telemetry (repro.obs): queue/slot gauges, request
        # lifecycle counters + spans. Everything recorded here is state
        # the scheduler already holds — never a device sync. The NULL
        # registry/tracer make the disabled path free.
        self.metrics = metrics if metrics is not None else M.NULL
        self.tracer = tracer if tracer is not None else Tr.NULL
        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[Request]] = [None] * batch_size
        self._rid = itertools.count()
        # admission template: the init_state schema itself, so a field
        # added there is automatically reset on every slot recycle — minus
        # "t", the global device step counter, which admission must not
        # rewind (it is the clock gen_step/TTFT attribution is built on)
        self._template = jax.tree.map(
            np.asarray, init_state(batch_size, max_prompt_len,
                                   max_new_cap, spec_k=spec_k))
        self._template.pop("t")
        # global (non-(B, ...)) speculative telemetry must survive slot
        # recycling too — admission's masked update is per-row only
        for k in ("spec_hist", "spec_drafted", "spec_emitted"):
            self._template.pop(k, None)

    # -- queue ---------------------------------------------------------

    def submit(self, req: Request) -> int:
        if not req.prompt:
            raise ValueError("empty prompt")
        if len(req.prompt) > self.max_prompt_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds engine "
                f"max_prompt_len {self.max_prompt_len}")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if req.max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"max_new_tokens {req.max_new_tokens} exceeds engine "
                f"max_new_cap {self.max_new_cap}")
        req.sampling.validate(self.vocab_size)
        req.rid = next(self._rid)
        req.submit_time = time.time()
        self.queue.append(req)
        self.metrics.counter("serve_requests_submitted_total").inc()
        self.metrics.gauge("serve_queue_depth").set(len(self.queue))
        self.tracer.begin("request", req.rid, ts=req.submit_time,
                          rid=req.rid, prompt_len=len(req.prompt),
                          max_new=req.max_new_tokens)
        return req.rid

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def running(self) -> int:
        return sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        return self.pending > 0 or self.running > 0

    # -- admission -----------------------------------------------------

    def admit(self, state, cache):
        """Fill free slots from the queue in ONE FIFO pass (a slot-pinned
        request only ever enters its own slot and, while that slot is
        busy, waits without blocking later requests). Returns
        (state, cache, rows): ONE jitted device call (batch-shaped mask
        update + cache-row reset) regardless of how many requests are
        admitted. O(queue + slots·log slots), no mutation of the deque
        mid-iteration.

        With a KV pool, admission also passes a page-budget gate: the
        request's worst-case page span must be reservable (free +
        evictable pages) after mapping any shared-prefix pages. A
        page-starved request at the queue head stops the whole pass
        (backpressure) rather than being overtaken — FIFO order is how
        large requests stay starvation-free. Pinned requests waiting on a
        *busy slot* still step aside without blocking the queue; the page
        gate only ever fires for requests whose slot is available.
        """
        rows, reqs = [], []
        pages_of = {}
        free = [i for i in range(self.batch_size) if self.slots[i] is None]
        heapq.heapify(free)
        free_set = set(free)
        kept: collections.deque = collections.deque()
        while self.queue:
            if not free_set:        # nothing can admit: keep order, stop
                kept.extend(self.queue)
                self.queue.clear()
                break
            r = self.queue.popleft()
            if r.slot is not None:
                if r.slot not in free_set:
                    kept.append(r)
                    continue
                i = r.slot
            else:
                i = heapq.heappop(free)     # lowest free index, FIFO fill
                while i not in free_set:    # lazily skip pinned takeovers
                    i = heapq.heappop(free)
            if self.pool is not None:
                total = len(r.prompt) + r.max_new_tokens - 1
                got = self.pool.try_admit(i, r.prompt, total)
                if got is None:
                    # backpressure: r keeps the queue head; nothing
                    # behind it may jump ahead of a page-starved request
                    if r.slot is None:
                        heapq.heappush(free, i)
                    kept.append(r)
                    kept.extend(self.queue)
                    self.queue.clear()
                    break
                pages_of[i], r.reused_tokens = got
            free_set.remove(i)
            self.slots[i] = r
            rows.append(i)
            reqs.append(r)
        self.queue = kept
        if rows:
            now = time.time()
            mets = self.metrics
            mets.counter("serve_requests_admitted_total").inc(len(rows))
            mets.gauge("serve_queue_depth").set(len(self.queue))
            mets.gauge("serve_slots_occupied").set(self.running)
            wait = mets.histogram("serve_queue_wait_seconds")
            for i, r in zip(rows, reqs):
                wait.observe(now - r.submit_time)
                self.tracer.annotate(r.rid, slot=i)
        if not rows:
            return state, cache, rows

        b = self.batch_size
        new = {k: v.copy() for k, v in self._template.items()}
        mask = np.zeros((b,), bool)
        for i, r in zip(rows, reqs):
            s = r.sampling.validate(self.vocab_size)
            ru = r.reused_tokens
            mask[i] = True
            # with a reused prefix the row starts mid-prompt: its first
            # forced token and cache position skip the resident span, and
            # its PRNG stream is pre-advanced by the splits the skipped
            # prefill steps would have consumed (sampled streams stay
            # token-identical to a dense engine)
            new["tok"][i, 0] = r.prompt[ru]
            new["cache_index"][i] = ru
            new["active"][i] = True
            new["prompt_buf"][i, :len(r.prompt)] = r.prompt
            new["prompt_len"][i] = len(r.prompt)
            new["max_new"][i] = r.max_new_tokens
            new["eos"][i] = NO_EOS if r.eos_token is None else r.eos_token
            new["temperature"][i] = s.temperature
            new["top_k"][i] = s.top_k
            new["top_p"][i] = s.top_p
            key = jax.random.PRNGKey(s.seed)
            if ru:
                key = _advance_rng(key, jnp.int32(ru))
            new["rng"][i] = np.asarray(key)
        if self.pool is not None:
            pth = np.full((b, cache["pt"].shape[1]), -1, np.int32)
            for i in rows:
                pg = pages_of[i]
                pth[i, :len(pg)] = pg
            state, cache = _apply_admission_paged(
                state, cache, jnp.asarray(mask),
                {k: jnp.asarray(v) for k, v in new.items()},
                jnp.asarray(pth))
        else:
            state, cache = _apply_admission(
                state, cache, jnp.asarray(mask),
                {k: jnp.asarray(v) for k, v in new.items()})
        return state, cache, rows

    # -- retirement ----------------------------------------------------

    def finished_rows(self, done_host, active_host) -> List[int]:
        """Slot indices holding a finished, not-yet-retired request."""
        return [i for i in range(self.batch_size)
                if self.slots[i] is not None
                and bool(done_host[i]) and bool(active_host[i])]

    def retire(self, state, rows, out_host, n_out_host,
               finish_host, lp_host=None) -> tuple:
        """Free the slots of ``rows`` and return (new_state, completions).
        ``out_host``/``n_out_host``/``finish_host``/``lp_host`` are host
        copies (``lp_host``: per-token logprobs, optional)."""
        comps = []
        now = time.time()
        mets = self.metrics
        ttft_h = mets.histogram("serve_ttft_seconds")
        # ITL/step-wall carry a decode_kernel label so the fused and
        # dense paths separate in traces; TTFT stays unlabeled (it is
        # admission-dominated, not decode-path-dominated)
        itl_labels = {"decode_kernel": self.decode_kernel}
        if self.spec_k:
            itl_labels["spec_k"] = self.spec_k
        itl_h = mets.histogram("serve_itl_seconds", itl_labels)
        gen_c = mets.counter("serve_generated_tokens_total")
        for i in rows:
            req = self.slots[i]
            n = int(n_out_host[i])
            c = Completion(
                rid=req.rid,
                tokens=[int(t) for t in out_host[i][:n]],
                prompt=req.prompt,
                finish_reason=_FINISH_REASONS.get(int(finish_host[i]),
                                                  "length"),
                submit_time=req.submit_time,
                first_token_time=req.first_token_time,
                finish_time=now,
                logprobs=([] if lp_host is None
                          else [float(x) for x in lp_host[i][:n]]),
            )
            comps.append(c)
            self.slots[i] = None
            if self.pool is not None:
                # decref the row's pages: registered prefix pages stay
                # cached for future hits, private ones return to the
                # free list — this replaces dense row zeroing
                self.pool.release_row(i)
            # telemetry from values already on host: TTFT attributed to
            # the device-side first-token step (engine fills
            # first_token_time before calling retire), ITL as the mean
            # inter-token gap over the row's generated tokens.
            gen_c.inc(n)
            mets.counter("serve_requests_finished_total",
                         {"reason": c.finish_reason}).inc()
            ttft = None
            if c.first_token_time is not None:
                ttft = c.first_token_time - c.submit_time
                ttft_h.observe(ttft)
                if n > 1:
                    itl_h.observe((now - c.first_token_time) / (n - 1))
            self.tracer.end(
                req.rid, ts_end=now, n_tokens=n,
                finish_reason=c.finish_reason,
                ttft_s=ttft, admit_step=req.admit_step)
        mets.gauge("serve_slots_occupied").set(self.running)
        mask = np.zeros((self.batch_size,), bool)
        mask[rows] = True
        state = _apply_retirement(state, jnp.asarray(mask))
        return state, comps
