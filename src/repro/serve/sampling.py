"""Device-side token sampling with per-request parameters.

Every knob is a *per-row vector* so one jitted call serves a heterogeneous
batch: row 0 can decode greedily while row 1 runs temperature-0.8 top-k-40
nucleus sampling. ``temperature == 0`` selects greedy for that row — the
whole policy surface lives in arrays, never in Python control flow, so the
engine's decode step stays one jit with no per-row host sync.

One descending sort of the (B, V) logits serves both the top-k threshold
(k-th largest value per row, with per-row k) and the top-p nucleus cutoff
(first prefix whose probability mass reaches p). That is O(B·V log V)
device work against the O(B·V) logits the step already holds — the serve
path where the paper notes full logits are cheap (§3.2).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_NEG_INF = jnp.finfo(jnp.float32).min


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling policy (host-side carrier).

    temperature: 0.0 => greedy (argmax); > 0 => softmax sampling.
    top_k: 0 => off; otherwise keep the k highest-logit tokens.
    top_p: 1.0 => off; otherwise keep the smallest prefix of the sorted
        distribution with cumulative probability >= top_p (the first token
        is always kept).
    seed: per-request PRNG seed — resubmitting the same request replays
        the same tokens regardless of what else shares the batch.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def validate(self, vocab_size: int) -> "SamplingParams":
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if not 0 <= self.top_k <= vocab_size:
            raise ValueError(f"top_k must be in [0, {vocab_size}], "
                             f"got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        return self


GREEDY = SamplingParams()


def greedy(logits):
    """(B, V) -> (B,) argmax tokens."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _filter_top_k(sorted_desc, scaled, top_k):
    """Mask logits below each row's k-th largest (per-row k; 0 = off)."""
    v = scaled.shape[-1]
    k_idx = jnp.clip(top_k - 1, 0, v - 1)[:, None]
    kth = jnp.take_along_axis(sorted_desc, k_idx, axis=-1)
    keep = (top_k[:, None] <= 0) | (scaled >= kth)
    return jnp.where(keep, scaled, _NEG_INF)


def _filter_top_p(sorted_desc, scaled, top_p):
    """Nucleus cutoff: keep the shortest sorted prefix reaching mass p."""
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # a sorted position is kept while the mass *before* it is < p; the
    # first position is always kept (csum - probs == 0 there)
    in_nucleus = (csum - probs) < top_p[:, None]
    thr = jnp.min(jnp.where(in_nucleus, sorted_desc, jnp.inf), axis=-1)
    keep = (top_p[:, None] >= 1.0) | (scaled >= thr[:, None])
    return jnp.where(keep, scaled, _NEG_INF)


def sample_tokens(logits, keys, temperature, top_k, top_p):
    """One sampled token per row, fully on device.

    logits: (B, V) f32; keys: (B,) batch of PRNG keys (uint32 (B, 2));
    temperature/top_p: (B,) f32; top_k: (B,) int32. Rows with
    ``temperature == 0`` take the argmax (their PRNG key is ignored); an
    all-greedy batch skips the sort/filter pipeline entirely via
    ``lax.cond`` (only the taken branch runs), so the default decode path
    stays a plain argmax.
    Returns (B,) int32.
    """
    logits = logits.astype(jnp.float32)
    arg = greedy(logits)

    def drawn(_):
        scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
        sorted_desc = -jnp.sort(-scaled, axis=-1)
        filtered = _filter_top_k(sorted_desc, scaled, top_k)
        # nucleus on the *already top-k-filtered* distribution would change
        # the sorted prefix; following vLLM we apply both filters to the
        # same temperature-scaled logits and intersect the keep sets.
        filtered = _filter_top_p(sorted_desc, filtered, top_p)
        d = jax.vmap(jax.random.categorical)(keys, filtered)
        return jnp.where(temperature <= 0.0, arg, d.astype(jnp.int32))

    return jax.lax.cond(jnp.any(temperature > 0.0), drawn,
                        lambda _: arg, None)
