"""Device-side token sampling with per-request parameters.

Every knob is a *per-row vector* so one jitted call serves a heterogeneous
batch: row 0 can decode greedily while row 1 runs temperature-0.8 top-k-40
nucleus sampling. ``temperature == 0`` selects greedy for that row — the
whole policy surface lives in arrays, never in Python control flow, so the
engine's decode step stays one jit with no per-row host sync.

Two sampling paths share this policy surface:

* **Fused (default serve path)** — :func:`sample_tokens_fused` routes the
  last hidden state straight into ``kernels.decode_sample``: the ``(B, V)``
  logit matrix is never materialized and the decode step's HBM traffic
  drops by the whole vocab-logit write/read. This is the serving-side dual
  of the paper's training claim: §3.2 only licenses full logits for a
  *single* token's forward, and a continuous-batching engine pays that
  `(B, V)` cost (plus an ``O(B·V log V)`` sort for top-k/top-p) on *every*
  step — exactly the waste CCE eliminates from training.
* **Dense (fallback + golden oracle)** — :func:`sample_tokens` keeps the
  explicit-logits pipeline: one descending sort of the ``(B, V)`` logits
  serves both the top-k threshold and the top-p nucleus cutoff. Batches
  where no row filters (every ``top_k == 0`` and ``top_p >= 1``) skip the
  sort entirely. Greedy decode is token-identical between the two paths;
  the golden serve tests pin that.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.decode_sample import decode_sample as _decode_sample

_NEG_INF = jnp.finfo(jnp.float32).min


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling policy (host-side carrier).

    temperature: 0.0 => greedy (argmax); > 0 => softmax sampling.
    top_k: 0 => off; otherwise keep the k highest-logit tokens.
    top_p: 1.0 => off; otherwise keep the smallest prefix of the sorted
        distribution with cumulative probability >= top_p (the first token
        is always kept).
    seed: per-request PRNG seed — resubmitting the same request replays
        the same tokens regardless of what else shares the batch.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def validate(self, vocab_size: int) -> "SamplingParams":
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if not 0 <= self.top_k <= vocab_size:
            raise ValueError(f"top_k must be in [0, {vocab_size}], "
                             f"got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        return self


GREEDY = SamplingParams()


def greedy(logits):
    """(B, V) -> (B,) argmax tokens."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _filter_top_k(sorted_desc, scaled, top_k):
    """Mask logits below each row's k-th largest (per-row k; 0 = off)."""
    v = scaled.shape[-1]
    k_idx = jnp.clip(top_k - 1, 0, v - 1)[:, None]
    kth = jnp.take_along_axis(sorted_desc, k_idx, axis=-1)
    keep = (top_k[:, None] <= 0) | (scaled >= kth)
    return jnp.where(keep, scaled, _NEG_INF)


def _filter_top_p(sorted_desc, scaled, top_p):
    """Nucleus cutoff: keep the shortest sorted prefix reaching mass p."""
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # a sorted position is kept while the mass *before* it is < p; the
    # first position is always kept (csum - probs == 0 there)
    in_nucleus = (csum - probs) < top_p[:, None]
    thr = jnp.min(jnp.where(in_nucleus, sorted_desc, jnp.inf), axis=-1)
    keep = (top_p[:, None] >= 1.0) | (scaled >= thr[:, None])
    return jnp.where(keep, scaled, _NEG_INF)


def sample_tokens(logits, keys, temperature, top_k, top_p, *,
                  return_logprob: bool = False):
    """One sampled token per row, fully on device (dense path).

    logits: (B, V) f32; keys: (B,) batch of PRNG keys (uint32 (B, 2));
    temperature/top_p: (B,) f32; top_k: (B,) int32. Rows with
    ``temperature == 0`` take the argmax (their PRNG key is ignored); an
    all-greedy batch skips the sort/filter pipeline entirely via
    ``lax.cond`` (only the taken branch runs), and a sampled batch where
    no row filters (every ``top_k == 0`` and ``top_p >= 1``) skips the
    ``O(B·V log V)`` sort the same way — pure-temperature decode is one
    softmax draw.

    Returns (B,) int32 tokens, or ``(tokens, logprobs)`` with
    ``return_logprob=True``. Greedy logprobs are under the raw softmax;
    filtered rows report the *renormalized* kept-set logprob (the same
    contract as the fused kernel, DESIGN.md §10).
    """
    logits = logits.astype(jnp.float32)
    arg = greedy(logits)
    b = logits.shape[0]
    rows = jnp.arange(b)

    def greedy_lp():
        lsm = jax.nn.log_softmax(logits, axis=-1)
        return lsm[rows, arg]

    def greedy_only(_):
        if not return_logprob:
            return arg
        return arg, greedy_lp()

    def drawn(_):
        scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]

        def with_filters(_):
            sorted_desc = -jnp.sort(-scaled, axis=-1)
            filtered = _filter_top_k(sorted_desc, scaled, top_k)
            # nucleus on the *already top-k-filtered* distribution would
            # change the sorted prefix; following vLLM we apply both
            # filters to the same temperature-scaled logits and intersect
            # the keep sets.
            return _filter_top_p(sorted_desc, filtered, top_p)

        filtered = jax.lax.cond(
            jnp.any((top_k > 0) | (top_p < 1.0)), with_filters,
            lambda _: scaled, None)
        d = jax.vmap(jax.random.categorical)(keys, filtered)
        tok = jnp.where(temperature <= 0.0, arg, d.astype(jnp.int32))
        if not return_logprob:
            return tok
        kept_lsm = jax.nn.log_softmax(filtered, axis=-1)
        lp = jnp.where(temperature <= 0.0, greedy_lp(),
                       kept_lsm[rows, tok])
        return tok, lp

    return jax.lax.cond(jnp.any(temperature > 0.0), drawn,
                        greedy_only, None)


def sample_tokens_fused(hidden, C, keys, temperature, top_k, top_p, *,
                        vocab: int, softcap: float | None = None,
                        with_filter: bool = True,
                        with_sample: bool = True,
                        use_kernel: bool | None = None):
    """Logit-free sampling: last hidden states straight to tokens.

    hidden: (B, D) last-position hidden states; C: (V_pad, D) classifier
    rows; remaining args as :func:`sample_tokens`. Streams ``C^T h``
    blockwise through ``kernels.decode_sample`` — the ``(B, V)`` logits
    never exist — and returns ``(tokens (B,) int32, logprobs (B,) f32)``.
    ``with_filter`` and ``with_sample`` must be static Python bools: pass
    ``with_filter=False`` when every sampled row in the batch has
    ``top_k == 0`` and ``top_p >= 1`` to skip the histogram-threshold
    sweeps, and ``with_sample=False`` when every row is greedy
    (``temperature == 0``) to additionally skip the Gumbel noise hash —
    the engine selects both host-side from the admitted requests'
    :class:`SamplingParams`.
    """
    tok, lp = _decode_sample(
        hidden, C, keys, temperature, top_k, top_p, vocab=vocab,
        softcap=softcap, with_filter=with_filter,
        with_sample=with_sample, use_kernel=use_kernel)
    return tok, lp


def verify_tokens_fused(hidden, C, keys, temperature, top_k, top_p, *,
                        labels, exclude, vocab: int,
                        softcap: float | None = None,
                        with_filter: bool = True,
                        with_sample: bool = True,
                        use_kernel: bool | None = None):
    """Speculative-verification sweep (DESIGN.md §12): the fused
    projection->sample pass of :func:`sample_tokens_fused` extended with
    the two per-row extras the draft/verify loop needs, still logit-free:

      * ``labels (B,) int32`` — the draft token proposed at each
        position; the sweep additionally accumulates its probability
        mass online and returns ``label_lp``, the target log-probability
        of the draft under the row's sampling distribution (raw softmax
        for greedy rows, renormalized kept-set for filtered rows) —
        exactly the acceptance-test numerator, with no ``(B, V)`` gather;
      * ``exclude (B,) int32`` (-1 = none) — a token masked out of the
        *sampled* pick only (greedy argmax and the reported LSEs are
        untouched), which is how the rejection bonus draws from the
        residual ``max(p - q, 0)`` support for greedy drafters: the
        rejected draft token can never be re-proposed.

    Returns ``(tokens (B,), logprobs (B,), label_lp (B,))``.
    """
    return _decode_sample(
        hidden, C, keys, temperature, top_k, top_p, vocab=vocab,
        softcap=softcap, with_filter=with_filter,
        with_sample=with_sample, use_kernel=use_kernel,
        labels=labels, exclude=exclude)
