"""Block-paged KV allocator with copy-free shared-prefix reuse.

The serve engine's dense layout reserves a ``(B, max_len, ...)`` KV buffer
per slot, so every request pays worst-case context memory and the number
of concurrent slots is hard-coupled to ``max_len``. This module decouples
them the same way the training losses decouple from the dense logit
matrix: never materialize worst-case state you don't need.

Physical layout (device side, built by ``transformer.init_cache``):

  * every dense-attention layer holds a page *pool* ``(num_pages,
    page_size, hkv, hd)`` instead of per-slot rows;
  * ONE page table ``cache["pt"]`` of shape ``(B, ceil(max_len /
    page_size))`` int32 is shared by all layers — entry ``pt[b, j]`` is
    the physical page backing logical page ``j`` of slot ``b`` (``-1`` =
    unmapped). A page id is valid in every layer's pool simultaneously,
    so one logical allocation reserves the page across the whole stack.

Host lifecycle (this module — pure Python, zero device syncs):

  * every physical page is in exactly ONE of three states:
      - **free**: on the free list;
      - **in use**: mapped by >= 1 slot (``ref[p]`` = number of mapping
        rows);
      - **cached**: refcount zero but still registered in the prefix
        registry — reusable by a future request, evictable (LRU) under
        allocation pressure.
  * admission reserves the row's whole worst-case page span
    (``ceil((prompt_len + max_new - 1) / page_size)``) up front, so the
    engine never allocates mid-flight — no extra device syncs, no
    deadlock between running rows;
  * **copy-free prefix reuse**: full page-aligned prompt prefixes are
    hashed into a chained registry ``(parent_page_id, page_tokens) ->
    page_id``. A new request walks the chain and maps already-resident
    pages straight into its table with a refcount bump — no copy is
    needed because a shared prefix occupies identical absolute positions
    (RoPE'd K/V are position-dependent but prefix-identical), and the
    row's own writes start strictly after the reused span;
  * retirement decrefs the row's pages; registered pages stay cached,
    private ones return to the free list. ``reset_cache_rows`` only
    resets the row's page-table row — page freeing replaces row zeroing.

Publication timing: a full prompt page becomes registry-visible only once
the engine's host-side prefill mirror shows the row has consumed past it.
Device program order then guarantees the page's K/V writes were enqueued
before any later step that could read them through a reused mapping.

Speculative decoding never reaches this module: a verify round may write
K/V for draft tokens that end up rejected, but those positions lie inside
the row's already-reserved page span and past its committed length — the
next round's forward overwrites them before anything can read them (see
``serve/speculative.py``, "Rollback semantics"). Host page tables,
refcounts and the prefix registry are invariant across a speculative
round, fully-rejected or not (pinned by
``tests/test_speculative.py::test_spec_kvpool_rollback_invariants``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.obs import metrics as M


def pages_for(n_positions: int, page_size: int) -> int:
    """Pages needed to back ``n_positions`` KV slots."""
    return -(-n_positions // page_size)


@dataclasses.dataclass
class _Pending:
    """A not-yet-published full prompt page of a running row."""
    page_id: int
    tokens: Tuple[int, ...]
    ready_at: int               # publish once this many prompt tokens are
                                # resident in the cache


class KVPool:
    """Host-side page allocator: free list + refcounts + prefix registry.

    All methods are O(pages touched); nothing here ever touches the
    device. The engine owns exactly one pool and threads it into the
    scheduler (admission/retirement) and its own step loop (publication).
    """

    def __init__(self, page_size: int, num_pages: int,
                 metrics: M.Registry | None = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.page_size = page_size
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.ref: List[int] = [0] * num_pages
        # prefix registry: (parent_page_id, page_tokens) -> page_id, with
        # parent -1 for a prompt's first page. key_of is the reverse map;
        # lru orders every registered page oldest-first for eviction.
        self.registry: Dict[tuple, int] = {}
        self.key_of: Dict[int, tuple] = {}
        self.lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._rows: Dict[int, List[int]] = {}
        self._pending: Dict[int, List[_Pending]] = {}
        self._publish_parent: Dict[int, int] = {}
        # cumulative stats (host floats; also exported as obs counters)
        self.reused_pages_total = 0
        self.published_pages_total = 0
        self.evicted_pages_total = 0
        self.hit_requests_total = 0
        self.admitted_requests_total = 0
        self.prompt_pages_total = 0     # full prompt pages across admits
        self.peak_pages = 0             # max(in_use + cached) ever
        self.metrics = metrics if metrics is not None else M.NULL
        self.metrics.gauge("serve_kvpool_pages_total").set(num_pages)
        self._export()

    # -- bookkeeping helpers -------------------------------------------

    def _export(self) -> None:
        """Refresh occupancy gauges from host state (never a sync)."""
        in_use = self.num_pages - len(self.free) - self.cached_pages
        self.peak_pages = max(self.peak_pages, self.num_pages -
                              len(self.free))
        m = self.metrics
        m.gauge("serve_kvpool_free_pages").set(len(self.free))
        m.gauge("serve_kvpool_inuse_pages").set(in_use)
        m.gauge("serve_kvpool_cached_pages").set(self.cached_pages)
        m.gauge("serve_kvpool_peak_pages").set(self.peak_pages)

    @property
    def cached_pages(self) -> int:
        return sum(1 for p in self.key_of if self.ref[p] == 0)

    def _match(self, prompt, limit: int) -> List[int]:
        """Walk the registry chain over the first ``limit`` prompt pages."""
        P, parent, out = self.page_size, -1, []
        for i in range(limit):
            pid = self.registry.get((parent, tuple(prompt[i * P:
                                                         (i + 1) * P])))
            if pid is None:
                break
            out.append(pid)
            parent = pid
        return out

    def _evict_one(self, keep: set) -> None:
        """Drop the LRU cached page (refcount 0, not in ``keep``) back to
        the free list, unregistering its prefix key."""
        for p in self.lru:
            if self.ref[p] == 0 and p not in keep:
                del self.lru[p]
                del self.registry[self.key_of.pop(p)]
                self.free.append(p)
                self.evicted_pages_total += 1
                self.metrics.counter(
                    "serve_kvpool_evicted_pages_total").inc()
                return
        raise RuntimeError("kvpool: eviction requested with no evictable "
                           "page (capacity check is broken)")

    # -- admission ------------------------------------------------------

    def try_admit(self, row: int, prompt, total_positions: int
                  ) -> Optional[Tuple[List[int], int]]:
        """Reserve the full page span for a request needing
        ``total_positions`` cache slots in slot ``row``.

        Returns ``(page_ids, reused_tokens)`` — ``page_ids`` is the row's
        logical->physical table (reused prefix pages first), and
        ``reused_tokens`` is the page-aligned prefix length whose K/V is
        already resident (prefill skips straight past it). Returns None
        when the pool cannot supply the span — the scheduler treats that
        as backpressure and stops admitting to preserve FIFO order.
        """
        if row in self._rows:
            raise RuntimeError(f"kvpool: row {row} already mapped")
        P = self.page_size
        n_logical = pages_for(total_positions, P)
        # reuse only full prompt pages, and always leave >= 1 prompt token
        # to teacher-force (the last prompt position's logits produce the
        # first generated token)
        matched = self._match(prompt, min((len(prompt) - 1) // P,
                                          n_logical))
        keep = set(matched)
        evictable = sum(1 for p in self.lru
                        if self.ref[p] == 0 and p not in keep)
        need = n_logical - len(matched)
        if len(self.free) + evictable < need:
            return None
        for p in matched:
            self.ref[p] += 1
            self.lru.move_to_end(p)
        alloc: List[int] = []
        for _ in range(need):
            if not self.free:
                self._evict_one(keep)
            p = self.free.pop()
            self.ref[p] += 1
            alloc.append(p)
        pages = matched + alloc
        self._rows[row] = pages
        # queue publication of the remaining full prompt pages; ready once
        # the engine reports the page's last token resident in the cache
        full = len(prompt) // P
        self._pending[row] = [
            _Pending(pages[i], tuple(prompt[i * P:(i + 1) * P]),
                     (i + 1) * P)
            for i in range(len(matched), full)]
        self._publish_parent[row] = matched[-1] if matched else -1
        reused = len(matched) * P
        self.admitted_requests_total += 1
        self.prompt_pages_total += full
        if matched:
            self.hit_requests_total += 1
            self.reused_pages_total += len(matched)
            m = self.metrics
            m.counter("serve_prefix_hit_requests_total").inc()
            m.counter("serve_prefix_pages_reused_total").inc(len(matched))
        self._export()
        return pages, reused

    # -- publication ----------------------------------------------------

    def publish_upto(self, row: int, resident_tokens: int) -> None:
        """Register the row's full prompt pages whose K/V writes the
        engine has already enqueued (``resident_tokens`` = prompt tokens
        consumed so far, including the reused span)."""
        pend = self._pending.get(row)
        if not pend:
            return
        done = 0
        for e in pend:
            if e.ready_at > resident_tokens:
                break
            key = (self._publish_parent[row], e.tokens)
            cur = self.registry.get(key)
            if cur is None:
                self.registry[key] = e.page_id
                self.key_of[e.page_id] = key
                self.lru[e.page_id] = None
                self._publish_parent[row] = e.page_id
                self.published_pages_total += 1
                self.metrics.counter(
                    "serve_prefix_pages_published_total").inc()
            else:
                # a concurrent row published the same prefix page first;
                # chain through theirs so future matches converge on one
                # physical copy
                self._publish_parent[row] = cur
            done += 1
        del pend[:done]
        if done:
            self._export()

    # -- retirement -----------------------------------------------------

    def release_row(self, row: int) -> None:
        """Decref every page mapped by ``row``. Registered pages stay
        cached for future prefix hits; private pages go back on the free
        list. Page freeing is what replaces dense row zeroing."""
        pages = self._rows.pop(row, [])
        self._pending.pop(row, None)
        self._publish_parent.pop(row, None)
        for p in pages:
            if self.ref[p] <= 0:
                raise RuntimeError(f"kvpool: double free of page {p}")
            self.ref[p] -= 1
            if self.ref[p] == 0 and p not in self.key_of:
                self.free.append(p)
        self._export()

    # -- introspection --------------------------------------------------

    def row_pages(self, row: int) -> List[int]:
        return list(self._rows.get(row, []))

    def available_pages(self) -> int:
        """Pages obtainable right now: free + evictable cached."""
        return len(self.free) + self.cached_pages

    def stats(self) -> dict:
        in_use = self.num_pages - len(self.free) - self.cached_pages
        return {
            "page_size": self.page_size,
            "num_pages": self.num_pages,
            "free_pages": len(self.free),
            "in_use_pages": in_use,
            "cached_pages": self.cached_pages,
            "peak_pages": self.peak_pages,
            "reused_pages_total": self.reused_pages_total,
            "published_pages_total": self.published_pages_total,
            "evicted_pages_total": self.evicted_pages_total,
            "hit_requests_total": self.hit_requests_total,
            "admitted_requests_total": self.admitted_requests_total,
            "prompt_pages_total": self.prompt_pages_total,
            "prefix_hit_rate": (self.reused_pages_total /
                                self.prompt_pages_total
                                if self.prompt_pages_total else 0.0),
        }

    def check_invariants(self) -> None:
        """Every page in exactly one of {free, in use, cached}; refcounts
        equal the number of rows mapping each page; the registry and its
        reverse map agree. Raises AssertionError on any violation."""
        n = self.num_pages
        free = set(self.free)
        assert len(free) == len(self.free), "duplicate page on free list"
        in_use = {p for p in range(n) if self.ref[p] > 0}
        cached = {p for p in self.key_of if self.ref[p] == 0}
        assert not (free & in_use), \
            f"refcounted pages on free list: {sorted(free & in_use)}"
        assert not (free & cached), \
            f"cached pages on free list: {sorted(free & cached)}"
        assert len(free) + len(in_use) + len(cached) == n, (
            f"page leak: free={len(free)} in_use={len(in_use)} "
            f"cached={len(cached)} != {n}")
        assert set(self.key_of) == set(self.lru), \
            "registry/LRU membership diverged"
        assert all(self.registry[k] == p for p, k in self.key_of.items()), \
            "registry reverse map diverged"
        counts = collections.Counter(
            p for pages in self._rows.values() for p in pages)
        for p in range(n):
            assert self.ref[p] == counts.get(p, 0), (
                f"page {p}: refcount {self.ref[p]} != "
                f"{counts.get(p, 0)} mapping rows")
        for row, pages in self._rows.items():
            assert len(pages) == len(set(pages)), \
                f"row {row} maps a page twice"
