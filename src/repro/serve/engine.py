"""Continuous-batching serving engine.

One jitted decode step serves the whole batch: model forward with per-row
``cache_index`` (``serve_step``), device-side sampling with per-request
parameters, prompt teacher-forcing and EOS/length stopping — all inside
:func:`repro.serve.scheduler.advance_slots`. The host performs exactly one
device sync per engine step (a single ``jax.device_get`` of the small
status vectors), independent of batch size; finished rows are fetched and
retired in one additional transfer only on the steps where something
finished.

Requests are admitted from the scheduler's queue whenever a slot is free —
mid-flight, without disturbing the other rows (their cache slots and
timelines are row-local). A finished row's KV rows are recycled
immediately (``reset_cache_rows``), so the batch never drains to the speed
of its slowest request.

``Engine.generate`` keeps the old lockstep API as a thin wrapper: submit
everything greedy, run to completion, return outputs in submission order.
"""

from __future__ import annotations

import functools
import time

import jax

from repro.models import transformer as T
from repro.serve import scheduler as sched
from repro.serve.sampling import GREEDY, SamplingParams


@functools.partial(jax.jit, static_argnames=("cfg", "max_len"),
                   donate_argnums=(1, 2))
def _engine_step(params, cache, state, enc_out, *, cfg, max_len):
    """serve_step + slot transition, fused into one jit.

    Module-level jit keyed on the (hashable) config: every Engine instance
    with the same cfg/shapes shares one compilation. cache/state are
    donated (both are immediately replaced by the caller) so the per-step
    KV dynamic-update-slices alias in place instead of copying the whole
    cache every token.
    """
    logits, cache = T.serve_step(params, cfg, cache, state["tok"],
                                 state["cache_index"], enc_out=enc_out)
    state = sched.advance_slots(state, logits, max_len=max_len)
    return cache, state


class Engine:
    """Slot-based continuous-batching engine over ``serve_step``.

    max_len: KV-cache length (prompt + generated tokens per request).
    batch_size: number of slots (concurrent requests per decode step).
    max_prompt_len / max_new_cap: capacities of the device-side prompt and
        output buffers (default: ``max_len``); they fix the jit signature.
    enc_out: optional encoder output for encoder-decoder models, shared by
        all rows (use a fresh engine per enc_out batch; rows map to slots
        in submission order).
    """

    def __init__(self, cfg, params, *, max_len: int = 512,
                 batch_size: int = 8, max_prompt_len: int | None = None,
                 max_new_cap: int | None = None, enc_out=None):
        if enc_out is not None and enc_out.shape[0] != batch_size:
            raise ValueError(
                f"enc_out has {enc_out.shape[0]} rows but the engine has "
                f"{batch_size} slots; slot i reads encoder row i, so they "
                f"must match (size batch_size to the encoder batch)")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self.enc_out = enc_out
        self.scheduler = sched.Scheduler(
            batch_size, max_prompt_len or max_len, max_new_cap or max_len,
            cfg.vocab_size)
        self.state = sched.init_state(batch_size,
                                      self.scheduler.max_prompt_len,
                                      self.scheduler.max_new_cap)
        self.cache = T.init_cache(cfg, batch_size, max_len)
        self.step_count = 0
        # with enc_out set, request i must land in slot i to meet its
        # encoder row — only guaranteed while no slot has been recycled
        self._enc_submits = 0

    # -- request API ---------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: int = 16,
               sampling: SamplingParams | None = None,
               eos_token: int | None = None) -> int:
        """Queue a request; returns its request id. The request starts
        decoding at the next ``step()`` with a free slot."""
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds the cache length "
                f"(max_len={self.max_len})")
        slot = None
        if self.enc_out is not None:
            if self._enc_submits >= self.batch_size:
                raise ValueError(
                    "with enc_out set, at most batch_size requests can be "
                    "submitted per engine: request i is pinned to slot i "
                    "to meet encoder row i, and there are only batch_size "
                    "encoder rows")
            # pin request i to slot i so a recycled lower slot can never
            # pair it with another request's encoder output
            slot = self._enc_submits
            self._enc_submits += 1
        return self.scheduler.submit(sched.Request(
            prompt=list(prompt), max_new_tokens=max_new_tokens,
            sampling=sampling or GREEDY, eos_token=eos_token, slot=slot))

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # -- decode loop ---------------------------------------------------

    def step(self, substeps: int = 1):
        """Admit, run ``substeps`` jitted decode steps, sync once.

        Returns the list of :class:`~repro.serve.scheduler.Completion`
        finished by this call. Host<->device traffic: the admission writes
        (only when something was queued), ONE status ``device_get`` — and
        one batched fetch of finished rows when there are completions.
        """
        if substeps < 1:
            raise ValueError(f"substeps must be >= 1, got {substeps}")
        self.state, self.cache, _ = self.scheduler.admit(
            self.state, self.cache)
        for _ in range(substeps):
            self.cache, self.state = _engine_step(
                self.params, self.cache, self.state, self.enc_out,
                cfg=self.cfg, max_len=self.max_len)
            self.step_count += 1
        return self._sync()

    def _sync(self):
        """The single per-step host sync: pull the status vectors, record
        first-token times, retire finished rows."""
        done, active, n_out = jax.device_get(
            (self.state["done"], self.state["active"],
             self.state["n_out"]))
        now = time.time()
        for i, req in enumerate(self.scheduler.slots):
            if (req is not None and req.first_token_time is None
                    and n_out[i] > 0):
                req.first_token_time = now
        rows = self.scheduler.finished_rows(done, active)
        if not rows:
            return []
        out_host, n_host, fin_host = jax.device_get(
            (self.state["out_buf"], self.state["n_out"],
             self.state["finish"]))
        self.state, comps = self.scheduler.retire(
            self.state, rows, out_host, n_host, fin_host)
        return comps

    def run(self, substeps: int = 1, max_steps: int | None = None):
        """Drive ``step()`` until all submitted work is finished; returns
        {rid: Completion}."""
        out = {}
        limit = max_steps if max_steps is not None else 10_000_000
        while self.has_work() and limit > 0:
            for c in self.step(substeps=substeps):
                out[c.rid] = c
            limit -= substeps
        return out

    # -- legacy API ----------------------------------------------------

    def generate(self, prompts: list, max_new_tokens: int = 16,
                 enc_out=None, sampling: SamplingParams | None = None,
                 eos_token: int | None = None) -> list:
        """Old lockstep-engine API: greedy-decode ``max_new_tokens`` for
        each prompt, outputs in submission order. Now a thin wrapper over
        the continuous-batching scheduler (prompt counts beyond
        ``batch_size`` simply queue)."""
        if enc_out is not None:
            if self.scheduler.has_work():
                raise ValueError("enc_out requires an idle engine "
                                 "(rows map to slots in submission order)")
            if enc_out.shape[0] != self.batch_size:
                raise ValueError(
                    f"enc_out has {enc_out.shape[0]} rows but the engine "
                    f"has {self.batch_size} slots; slot i reads encoder "
                    f"row i, so they must match")
            if len(prompts) > self.batch_size:
                raise ValueError("enc_out rows cannot exceed batch_size")
            self.enc_out = enc_out
            self._enc_submits = 0   # idle engine: slots refill from 0
        rids = [self.submit(p, max_new_tokens=max_new_tokens,
                            sampling=sampling, eos_token=eos_token)
                for p in prompts]
        comps = self.run()
        return [comps[r].tokens for r in rids]
