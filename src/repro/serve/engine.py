"""Batched serving engine (greedy decode, continuous-batching-lite).

Requests of different prompt lengths share one batch and one timeline: at
step t a row still inside its prompt is teacher-forced with its next prompt
token; rows past their prompt generate. Each row's KV cache only ever
contains its own tokens, so no padding/masking gymnastics are needed and
the step function stays a single ``serve_step`` jit.

Inference memory is O(B·V) for the one-position logits — the case the paper
notes is already cheap (§3.2); CCE is a training-time fix.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T


class Engine:
    def __init__(self, cfg, params, *, max_len: int = 512,
                 batch_size: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self._step = jax.jit(functools.partial(T.serve_step, cfg=cfg))

    def generate(self, prompts: list, max_new_tokens: int = 16,
                 enc_out=None) -> list:
        assert len(prompts) <= self.batch_size
        b = len(prompts)
        cache = T.init_cache(self.cfg, b, self.max_len)
        outputs: list[list[int]] = [[] for _ in range(b)]
        tok = jnp.asarray([[p[0]] for p in prompts], jnp.int32)

        t = 0
        while min(len(o) for o in outputs) < max_new_tokens:
            logits, cache = self._step(params=self.params, cache=cache,
                                       tokens=tok, cache_index=t,
                                       enc_out=enc_out)
            nxt = jnp.argmax(logits, axis=-1)
            next_tok = []
            for i, p in enumerate(prompts):
                if t + 1 < len(p):
                    next_tok.append(p[t + 1])          # prefill continues
                else:
                    tok_i = int(nxt[i])
                    if len(outputs[i]) < max_new_tokens:
                        outputs[i].append(tok_i)
                    next_tok.append(tok_i)
            tok = jnp.asarray(next_tok, jnp.int32)[:, None]
            t += 1
            if t >= self.max_len - 1:
                break
        return outputs
