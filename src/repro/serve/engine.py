"""Continuous-batching serving engine.

One jitted decode step serves the whole batch: model forward with per-row
``cache_index`` (``serve_step``), device-side sampling with per-request
parameters, prompt teacher-forcing and EOS/length stopping — all inside
:func:`repro.serve.scheduler.advance_slots`. The host performs exactly one
device sync per engine step (a single ``jax.device_get`` of the small
status vectors), independent of batch size; finished rows are fetched and
retired in one additional transfer only on the steps where something
finished.

Prompt ingestion is **chunked** (``prefill_chunk``): while any slot is
still inside its prompt, the engine swaps the single-token jit for a fused
prefill+decode jit (``serve_prefill``) in which prefilling rows consume up
to ``prefill_chunk`` prompt tokens per step — straight from the
device-side prompt buffer — while decoding rows advance one token as
usual. A 100-token prompt then costs ~100/chunk steps before its first
generated token instead of 100, without stalling the rows that are already
decoding and without any extra host traffic. Token streams are identical
to one-token teacher forcing (greedy AND sampled: each row's PRNG stream
is advanced per consumed token, not per step).

Requests are admitted from the scheduler's queue whenever a slot is free —
mid-flight, without disturbing the other rows (their cache slots and
timelines are row-local). A finished row's KV rows are recycled
immediately (``reset_cache_rows``), so the batch never drains to the speed
of its slowest request.

``Engine.generate`` keeps the old lockstep API as a thin wrapper: submit
everything greedy, run to completion, return outputs in submission order.
"""

from __future__ import annotations

import bisect
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.obs import metrics as M
from repro.obs import trace as Tr
from repro.serve import kvpool as KP
from repro.serve import scheduler as sched
from repro.serve import speculative as SP
from repro.serve.sampling import GREEDY, SamplingParams


@functools.partial(jax.jit, static_argnames=("cfg", "max_len"),
                   donate_argnums=(1, 2))
def _engine_step(params, cache, state, enc_out, *, cfg, max_len):
    """serve_step + slot transition, fused into one jit.

    Module-level jit keyed on the (hashable) config: every Engine instance
    with the same cfg/shapes shares one compilation. cache/state are
    donated (both are immediately replaced by the caller) so the per-step
    KV dynamic-update-slices alias in place instead of copying the whole
    cache every token.
    """
    logits, cache = T.serve_step(params, cfg, cache, state["tok"],
                                 state["cache_index"], enc_out=enc_out)
    state = sched.advance_slots(state, logits, max_len=max_len)
    return cache, state


@functools.partial(jax.jit, static_argnames=("cfg", "max_len", "chunk"),
                   donate_argnums=(1, 2))
def _engine_prefill_step(params, cache, state, enc_out, *, cfg, max_len,
                         chunk):
    """Piggyback chunked prefill: one fused jit in which rows still inside
    their prompt ingest up to ``chunk`` prompt tokens (gathered from the
    device-side prompt buffer — no extra host traffic) while decoding rows
    advance their usual single token (valid_len == 1). Prompt ingestion
    therefore neither stalls the decoding rows nor adds host syncs."""
    p = state["cache_index"]
    live = state["active"] & ~state["done"]
    in_prompt = live & (p < state["prompt_len"])
    n_tok = jnp.where(in_prompt,
                      jnp.minimum(chunk, state["prompt_len"] - p),
                      1).astype(jnp.int32)
    pcap = state["prompt_buf"].shape[1]
    idx = jnp.clip(p[:, None] + jnp.arange(chunk), 0, pcap - 1)
    ptoks = jnp.take_along_axis(state["prompt_buf"], idx, axis=1)
    toks = jnp.where(in_prompt[:, None], ptoks,
                     jnp.broadcast_to(state["tok"], ptoks.shape))
    logits, cache = T.serve_prefill(params, cfg, cache, toks, p, n_tok,
                                    enc_out=enc_out)
    state = sched.advance_slots(state, logits, max_len=max_len,
                                n_tok=n_tok, chunk=chunk)
    return cache, state


# Fused (logit-free) variants: the forward hands its last hidden states
# straight to the projection->sample kernel (kernels.decode_sample) and
# advance_slots consumes (token, logprob) — no (B, V) array exists
# anywhere in these jits (census-asserted by tests/test_serve.py).
# ``with_filter`` / ``with_sample`` are static: the engine picks both
# host-side from the live requests' SamplingParams, so an unfiltered
# batch never pays the histogram-threshold sweeps and an all-greedy
# batch never pays the Gumbel noise hash.

@functools.partial(jax.jit,
                   static_argnames=("cfg", "max_len", "with_filter",
                                    "with_sample"),
                   donate_argnums=(1, 2))
def _engine_step_fused(params, cache, state, enc_out, *, cfg, max_len,
                       with_filter, with_sample=True):
    keys, rng_carry = sched.sample_keys(state)
    (tok, lp), cache = T.serve_step(
        params, cfg, cache, state["tok"], state["cache_index"],
        enc_out=enc_out, return_logits=False,
        sample=(keys, state["temperature"], state["top_k"],
                state["top_p"]),
        with_filter=with_filter, with_sample=with_sample)
    state = sched.advance_slots(state, max_len=max_len,
                                fused=(tok, lp, rng_carry))
    return cache, state


@functools.partial(jax.jit,
                   static_argnames=("cfg", "max_len", "chunk",
                                    "with_filter", "with_sample"),
                   donate_argnums=(1, 2))
def _engine_prefill_step_fused(params, cache, state, enc_out, *, cfg,
                               max_len, chunk, with_filter,
                               with_sample=True):
    p = state["cache_index"]
    live = state["active"] & ~state["done"]
    in_prompt = live & (p < state["prompt_len"])
    n_tok = jnp.where(in_prompt,
                      jnp.minimum(chunk, state["prompt_len"] - p),
                      1).astype(jnp.int32)
    pcap = state["prompt_buf"].shape[1]
    idx = jnp.clip(p[:, None] + jnp.arange(chunk), 0, pcap - 1)
    ptoks = jnp.take_along_axis(state["prompt_buf"], idx, axis=1)
    toks = jnp.where(in_prompt[:, None], ptoks,
                     jnp.broadcast_to(state["tok"], ptoks.shape))
    keys, rng_carry = sched.sample_keys(state, n_tok, chunk)
    (tok, lp), cache = T.serve_prefill(
        params, cfg, cache, toks, p, n_tok, enc_out=enc_out,
        return_logits=False,
        sample=(keys, state["temperature"], state["top_k"],
                state["top_p"]),
        with_filter=with_filter, with_sample=with_sample)
    state = sched.advance_slots(state, max_len=max_len, n_tok=n_tok,
                                chunk=chunk, fused=(tok, lp, rng_carry))
    return cache, state


# Speculative decoding (DESIGN.md §12): one draft/verify round per jit
# call emits up to spec_k + 1 tokens per decode row. The round subsumes
# chunked prefill (prefilling rows use the window as a prompt chunk), so
# a speculative engine runs exactly ONE jit flavor per drafter — and the
# one-host-sync-per-step contract is unchanged (census-asserted by the
# sync auditor: no device_get outside Engine._sync).

def _spec_round(params, cache, state, enc_out, drafts, *, cfg, max_len,
                spec_k, with_filter, with_sample, replay):
    """Shared verify/accept/commit tail of both speculative jits."""
    window, n_tok, in_prompt, k_b = SP.build_windows(
        state, drafts, spec_k=spec_k, max_len=max_len)
    keys, carries = sched.sample_keys_all(state, spec_k + 1)
    p = state["cache_index"]
    hidden, new_cache = T.serve_prefill_spec(
        params, cfg, cache, window, p, n_tok, enc_out=enc_out)
    tok_s, lp_s, lab_lp = SP.run_verify_sweep(
        params, cfg, hidden, window, n_tok, keys, state,
        with_filter=with_filter, with_sample=with_sample)
    state, commit_len, _ = SP.accept_and_advance(
        state, window, n_tok, in_prompt, k_b, tok_s, lp_s, lab_lp, keys,
        carries, spec_k=spec_k, max_len=max_len)
    if replay:
        # recurrent/SWA-ring states carry the rejected tail: commit by
        # replaying ONLY the accepted prefix over the original cache
        # (masked re-write — positions past commit_len never enter)
        _, new_cache = T.serve_prefill_spec(
            params, cfg, cache, window, p, commit_len, enc_out=enc_out)
    return new_cache, state


@functools.partial(jax.jit,
                   static_argnames=("cfg", "max_len", "spec_k",
                                    "with_filter", "with_sample",
                                    "replay"),
                   donate_argnums=(1, 2))
def _engine_step_spec(params, cache, state, enc_out, *, cfg, max_len,
                      spec_k, with_filter, with_sample, replay):
    """Speculative round with the zero-cost n-gram/prompt-lookup
    drafter: proposals come from the row's own token history, entirely
    device-side — no extra parameters, no extra cache."""
    drafts = SP.ngram_drafts(state, spec_k)
    return _spec_round(params, cache, state, enc_out, drafts, cfg=cfg,
                       max_len=max_len, spec_k=spec_k,
                       with_filter=with_filter, with_sample=with_sample,
                       replay=replay)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "draft_cfg", "max_len",
                                    "spec_k", "with_filter",
                                    "with_sample", "replay"),
                   donate_argnums=(1, 2, 4))
def _engine_step_spec_draft(params, cache, state, draft_params,
                            draft_cache, enc_out, *, cfg, draft_cfg,
                            max_len, spec_k, with_filter, with_sample,
                            replay):
    """Speculative round with a small draft transformer (any config
    sharing the vocab). The draft cache first catches up on the window
    each row committed last round, then K greedy one-token steps on a
    throwaway fork produce the proposals — all in the same jit, so the
    draft loop adds zero host syncs."""
    draft_cache = SP.draft_catchup(draft_params, draft_cfg, draft_cache,
                                   state)
    drafts = SP.draft_propose(draft_params, draft_cfg, draft_cache,
                              state, spec_k)
    cache, state = _spec_round(params, cache, state, enc_out, drafts,
                               cfg=cfg, max_len=max_len, spec_k=spec_k,
                               with_filter=with_filter,
                               with_sample=with_sample, replay=replay)
    return cache, state, draft_cache


# slot recycling for the draft cache (same batch-shaped masked reset the
# scheduler applies to the target cache at admission)
_reset_draft_rows = jax.jit(T.reset_cache_rows)


class Engine:
    """Slot-based continuous-batching engine over ``serve_step``.

    max_len: KV-cache length (prompt + generated tokens per request).
    batch_size: number of slots (concurrent requests per decode step).
    max_prompt_len / max_new_cap: capacities of the device-side prompt and
        output buffers (default: ``max_len``); they fix the jit signature.
    prefill_chunk: prompt tokens a prefilling row ingests per engine step
        (1 = classic one-token teacher forcing). While any slot is still
        inside its prompt the engine runs the fused prefill+decode jit
        (``serve_prefill``); once every slot is decoding it drops back to
        the single-token jit, so steady-state decode pays nothing.
    enc_out: optional encoder output for encoder-decoder models, shared by
        all rows (use a fresh engine per enc_out batch; rows map to slots
        in submission order).
    metrics / tracer: a :class:`repro.obs.Registry` and
        :class:`repro.obs.Tracer` for per-step telemetry (TTFT/ITL
        histograms, queue/slot gauges, token-split counters, per-request
        spans). All of it piggybacks on the ONE per-step host sync the
        engine performs anyway — enabling metrics adds zero
        ``device_get``s and zero jit recompiles (asserted by
        tests/test_serve.py). Default: disabled (no-op twins).
    kv_page_size / kv_pages: block-paged KV layout for full-attention
        caches (:mod:`repro.serve.kvpool`). ``kv_page_size`` tokens per
        page; ``kv_pages`` physical pages shared by all slots (default:
        the dense-equivalent ``batch_size * ceil(max_len/page_size)``).
        Admission reserves a request's worst-case page span up front
        (page-budget gate with FIFO backpressure) and maps already-
        resident page-aligned prompt prefixes copy-free with a refcount
        bump — chunked prefill skips straight past reused pages. Default
        off (dense per-slot layout).
    decode_kernel: ``"dense"`` (explicit (B, V) logits + device sampler —
        the fallback and golden oracle) or ``"fused"`` (logit-free:
        ``kernels.decode_sample`` streams ``C^T h`` blockwise and the
        step emits only (token, logprob) per row). Greedy decode is
        token-identical between the two; sampled streams draw from the
        same per-row distribution but different noise (streaming
        Gumbel-max vs inverse-CDF). Default ``"dense"`` here; the serve
        CLI defaults to ``"fused"``.
    spec_k: speculative draft length (0 = off). Each engine step runs
        ONE draft/verify round (``repro.serve.speculative``) emitting up
        to ``spec_k + 1`` tokens per decode row: drafts are verified by
        a single multi-token forward scored with one fused
        projection->sample sweep — still logit-free, still one host
        sync per step. Greedy speculative output is token-identical to
        plain greedy; sampled rows draw from the same per-row
        distribution (acceptance ratio test + residual bonus sampling).
        Requires ``decode_kernel="fused"``.
    draft_cfg / draft_params: optional draft transformer (any config
        sharing the vocab) proposing the ``spec_k`` tokens; without
        one, the zero-cost n-gram/prompt-lookup drafter runs. The
        engine owns the draft cache and recycles its rows at admission.
    """

    def __init__(self, cfg, params, *, max_len: int = 512,
                 batch_size: int = 8, max_prompt_len: int | None = None,
                 max_new_cap: int | None = None, prefill_chunk: int = 1,
                 enc_out=None, metrics: M.Registry | None = None,
                 tracer: Tr.Tracer | None = None,
                 kv_page_size: int | None = None,
                 kv_pages: int | None = None,
                 decode_kernel: str = "dense",
                 spec_k: int = 0, draft_cfg=None, draft_params=None):
        if decode_kernel not in ("fused", "dense"):
            raise ValueError(
                f"decode_kernel must be 'fused' or 'dense', "
                f"got {decode_kernel!r}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k > 0 and decode_kernel != "fused":
            raise ValueError(
                "speculative decoding (spec_k > 0) verifies with the "
                "fused projection->sample sweep; it requires "
                "decode_kernel='fused'")
        if (draft_cfg is None) != (draft_params is None):
            raise ValueError(
                "draft_cfg and draft_params must be given together")
        if draft_cfg is not None and spec_k == 0:
            raise ValueError("a draft model requires spec_k > 0")
        if draft_cfg is not None and draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft model must share the vocab: draft vocab_size "
                f"{draft_cfg.vocab_size} != target {cfg.vocab_size}")
        if spec_k > 0 and cfg.sliding_window is not None and \
                "swa" in cfg.pattern_for(cfg.num_layers) and \
                spec_k + 1 > cfg.sliding_window:
            raise ValueError(
                f"spec_k + 1 = {spec_k + 1} exceeds the sliding window "
                f"({cfg.sliding_window}): a verification window must fit "
                f"the SWA ring")
        if enc_out is not None and enc_out.shape[0] != batch_size:
            raise ValueError(
                f"enc_out has {enc_out.shape[0]} rows but the engine has "
                f"{batch_size} slots; slot i reads encoder row i, so they "
                f"must match (size batch_size to the encoder batch)")
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self.decode_kernel = decode_kernel
        self.prefill_chunk = int(prefill_chunk)
        self.enc_out = enc_out
        self.metrics = metrics if metrics is not None else M.NULL
        self.tracer = tracer if tracer is not None else Tr.NULL
        self.metrics.gauge("serve_slots_total").set(batch_size)
        self.pool = None
        paged_kw = {}
        if kv_pages is not None and kv_page_size is None:
            raise ValueError("kv_pages requires kv_page_size")
        if kv_page_size is not None:
            if kv_page_size < 1:
                raise ValueError(
                    f"kv_page_size must be >= 1, got {kv_page_size}")
            n_logical = KP.pages_for(max_len, kv_page_size)
            pages = kv_pages if kv_pages is not None \
                else batch_size * n_logical
            if pages < 1:
                raise ValueError(f"kv_pages must be >= 1, got {pages}")
            self.pool = KP.KVPool(kv_page_size, pages,
                                  metrics=self.metrics)
            paged_kw = dict(kv_page_size=kv_page_size, kv_pages=pages)
        self.scheduler = sched.Scheduler(
            batch_size, max_prompt_len or max_len, max_new_cap or max_len,
            cfg.vocab_size, metrics=self.metrics, tracer=self.tracer,
            pool=self.pool, decode_kernel=decode_kernel, spec_k=spec_k)
        self.state = sched.init_state(batch_size,
                                      self.scheduler.max_prompt_len,
                                      self.scheduler.max_new_cap,
                                      spec_k=spec_k)
        self.cache = T.init_cache(cfg, batch_size, max_len, **paged_kw)
        # speculative decoding (spec_k > 0): drafter state. The draft
        # model keeps its own dense cache, recycled per slot at admission
        # just like the target cache; without one the n-gram drafter runs
        # stateless. _spec_prev mirrors the device-side telemetry
        # counters so _sync can emit host metrics as deltas.
        self.spec_k = int(spec_k)
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_cache = None
        if spec_k > 0 and draft_cfg is not None:
            self.draft_cache = T.init_cache(draft_cfg, batch_size,
                                            max_len)
        self._replay = SP.needs_replay(cfg) if spec_k > 0 else False
        self._spec_prev = ([0] * (spec_k + 2), 0, 0)
        self._spec_buckets = tuple(i + 0.5 for i in range(spec_k + 2))
        self.step_count = 0
        # host mirror of each slot's unconsumed prompt tokens; prefill
        # progress is host-deterministic (stopping can only hit generated
        # tokens), so no device sync is needed to pick the step flavor
        self._prefill_left = [0] * batch_size
        # (step_count, wall-clock) sync log: maps device step indices to
        # times, so a row's first-token step converts to a true TTFT at
        # retirement instead of being stamped at the next host sync
        self._times = [(0, time.time())]
        # with enc_out set, request i must land in slot i to meet its
        # encoder row — only guaranteed while no slot has been recycled
        self._enc_submits = 0

    # -- request API ---------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: int = 16,
               sampling: SamplingParams | None = None,
               eos_token: int | None = None) -> int:
        """Queue a request; returns its request id. The request starts
        decoding at the next ``step()`` with a free slot."""
        # the final sampled token is never fed back, so the last cache
        # position written is len(prompt) + max_new_tokens - 2: a request
        # with prompt + max_new == max_len + 1 still fits exactly
        if len(prompt) + max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) needs {len(prompt) + max_new_tokens - 1} "
                f"cache positions, exceeding the cache length "
                f"(max_len={self.max_len})")
        if self.pool is not None:
            need = KP.pages_for(len(prompt) + max_new_tokens - 1,
                                self.pool.page_size)
            if need > self.pool.num_pages:
                raise ValueError(
                    f"request needs {need} KV pages worst-case but the "
                    f"pool only has {self.pool.num_pages}; it could never "
                    f"be admitted (raise kv_pages or shrink the request)")
        slot = None
        if self.enc_out is not None:
            if self._enc_submits >= self.batch_size:
                raise ValueError(
                    "with enc_out set, at most batch_size requests can be "
                    "submitted per engine: request i is pinned to slot i "
                    "to meet encoder row i, and there are only batch_size "
                    "encoder rows")
            # pin request i to slot i so a recycled lower slot can never
            # pair it with another request's encoder output
            slot = self._enc_submits
            self._enc_submits += 1
        return self.scheduler.submit(sched.Request(
            prompt=list(prompt), max_new_tokens=max_new_tokens,
            sampling=sampling or GREEDY, eos_token=eos_token, slot=slot))

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # -- decode loop ---------------------------------------------------

    def step(self, substeps: int = 1):
        """Admit, run ``substeps`` jitted decode steps, sync once.

        Each substep runs the fused prefill+decode jit while any slot is
        still inside its prompt (ingesting up to ``prefill_chunk`` prompt
        tokens per prefilling row), and the single-token jit otherwise —
        chosen from host-side bookkeeping, never a device sync.

        Returns the list of :class:`~repro.serve.scheduler.Completion`
        finished by this call. Host<->device traffic: the admission writes
        (only when something was queued), ONE status ``device_get`` — and
        one batched fetch of finished rows when there are completions.
        """
        if substeps < 1:
            raise ValueError(f"substeps must be >= 1, got {substeps}")
        t_start = time.time()
        self._times.append((self.step_count, t_start))
        self.state, self.cache, rows = self.scheduler.admit(
            self.state, self.cache)
        for i in rows:
            req = self.scheduler.slots[i]
            # a reused prefix is already resident in the KV pool — prefill
            # starts past it (copy-free reuse; see repro.serve.kvpool)
            self._prefill_left[i] = len(req.prompt) - req.reused_tokens
            req.admit_step = self.step_count
            self.tracer.annotate(req.rid, admit_step=self.step_count,
                                 reused_tokens=req.reused_tokens)
        if rows and self.draft_cache is not None:
            mask = np.zeros((self.batch_size,), bool)
            mask[list(rows)] = True
            self.draft_cache = _reset_draft_rows(self.draft_cache,
                                                 jnp.asarray(mask))
        prefill_toks = 0
        fused = self.decode_kernel == "fused"
        # with_filter is a static jit arg picked from host-side request
        # state: True iff any live slot's SamplingParams filters. A row
        # finishing mid-substep can only leave with_filter conservatively
        # True — never incorrectly False.
        wf = fused and any(
            r is not None and (r.sampling.top_k > 0
                               or r.sampling.top_p < 1.0)
            for r in self.scheduler.slots)
        # with_sample likewise: False only when every live slot decodes
        # greedily — then the kernel sweep is a pure streaming argmax+LSE
        # with no Gumbel noise hash at all
        ws = fused and any(
            r is not None and r.sampling.temperature > 0.0
            for r in self.scheduler.slots)
        for _ in range(substeps):
            if self.spec_k:
                # the speculative round subsumes chunked prefill
                # (prefilling rows use the window as a prompt chunk), so
                # spec mode runs one jit flavor per drafter, always
                if self.draft_cache is not None:
                    (self.cache, self.state,
                     self.draft_cache) = _engine_step_spec_draft(
                        self.params, self.cache, self.state,
                        self.draft_params, self.draft_cache, self.enc_out,
                        cfg=self.cfg, draft_cfg=self.draft_cfg,
                        max_len=self.max_len, spec_k=self.spec_k,
                        with_filter=wf, with_sample=ws,
                        replay=self._replay)
                else:
                    self.cache, self.state = _engine_step_spec(
                        self.params, self.cache, self.state, self.enc_out,
                        cfg=self.cfg, max_len=self.max_len,
                        spec_k=self.spec_k, with_filter=wf,
                        with_sample=ws, replay=self._replay)
                used = self.spec_k + 1
            elif self.prefill_chunk > 1 and any(
                    left > 1 for left in self._prefill_left):
                if fused:
                    self.cache, self.state = _engine_prefill_step_fused(
                        self.params, self.cache, self.state, self.enc_out,
                        cfg=self.cfg, max_len=self.max_len,
                        chunk=self.prefill_chunk, with_filter=wf,
                        with_sample=ws)
                else:
                    self.cache, self.state = _engine_prefill_step(
                        self.params, self.cache, self.state, self.enc_out,
                        cfg=self.cfg, max_len=self.max_len,
                        chunk=self.prefill_chunk)
                used = self.prefill_chunk
            else:
                if fused:
                    self.cache, self.state = _engine_step_fused(
                        self.params, self.cache, self.state, self.enc_out,
                        cfg=self.cfg, max_len=self.max_len,
                        with_filter=wf, with_sample=ws)
                else:
                    self.cache, self.state = _engine_step(
                        self.params, self.cache, self.state, self.enc_out,
                        cfg=self.cfg, max_len=self.max_len)
                used = 1
            for i, req in enumerate(self.scheduler.slots):
                if req is not None and self._prefill_left[i] > 0:
                    consumed = min(used, self._prefill_left[i])
                    self._prefill_left[i] -= consumed
                    prefill_toks += consumed
            self.step_count += 1
        t_end = time.time()
        self._times.append((self.step_count, t_end))
        self._prune_times()
        if self.pool is not None:
            # publish full prompt pages whose K/V writes are now enqueued
            # (the host prefill ledger is deterministic; device program
            # order puts those writes before any later reuse). Must run
            # before _sync retires rows, so a finishing row's prefix pages
            # register before its references are dropped.
            for i, req in enumerate(self.scheduler.slots):
                if req is not None:
                    self.pool.publish_upto(
                        i, len(req.prompt) - self._prefill_left[i])
        # per-step telemetry from host-side bookkeeping only: the prompt
        # token split mirrors the deterministic prefill ledger (the device
        # consumed exactly these tokens), the wall histogram spans the
        # sync window this call just timed. Generated-token counts are
        # exact at retirement (scheduler.retire), so no status beyond the
        # usual _sync is ever pulled.
        mets = self.metrics
        if mets.enabled:
            mets.counter("serve_engine_steps_total").inc(substeps)
            mets.counter("serve_prefill_tokens_total").inc(prefill_toks)
            wall_labels = {"decode_kernel": self.decode_kernel}
            if self.spec_k:
                wall_labels["spec_k"] = self.spec_k
            mets.histogram(
                "serve_step_wall_seconds", wall_labels).observe(
                (t_end - t_start) / substeps)
            if fused:
                # HBM bytes the fused path did NOT move this step: the
                # (B, V_pad) f32 logit write/read the dense path pays,
                # minus the fused outputs (token + logprob = 8 B/row).
                # A speculative step sweeps every window position, so
                # the avoided buffer scales by spec_k + 1. Pure host
                # arithmetic — no device sync.
                avoided = self.batch_size * (self.spec_k + 1) * (
                    self.cfg.padded_vocab_size * 4 - 8)
                mets.gauge("serve_decode_hbm_bytes_avoided").set(avoided)
                mets.counter(
                    "serve_decode_hbm_bytes_avoided_total").inc(
                    avoided * substeps)
        return self._sync()

    def _step_time(self, s: int) -> float:
        """Wall-clock estimate for device step ``s`` by linear
        interpolation between the enclosing entries of the sync log."""
        times = self._times
        k = bisect.bisect_left(times, (s, float("-inf")))
        if k >= len(times):
            return times[-1][1]
        s1, t1 = times[k]
        if s1 == s or k == 0:
            return t1
        s0, t0 = times[k - 1]
        if s1 == s0:
            return t1
        return t0 + (t1 - t0) * (s - s0) / (s1 - s0)

    def _prune_times(self):
        """Drop sync-log entries no retirement can reference anymore:
        every live row's first token lands at or after its admission."""
        floor = self.step_count
        for i, req in enumerate(self.scheduler.slots):
            if req is not None and req.admit_step >= 0:
                floor = min(floor, req.admit_step)
        t = self._times
        k = 0
        while k + 1 < len(t) and t[k + 1][0] <= floor:
            k += 1
        del t[:k]

    def _sync(self):
        """The single per-step host sync: pull the status vectors, then
        retire finished rows (attributing each one's TTFT from the device
        step index its first token was generated at).

        With speculation on, the same ONE transfer also carries the
        device-side acceptance telemetry (a (spec_k+2,) histogram and
        two scalars) — spec metrics add zero extra device_gets."""
        pulls = (self.state["done"], self.state["active"])
        if self.spec_k:
            pulls += (self.state["spec_hist"], self.state["spec_drafted"],
                      self.state["spec_emitted"])
        got = jax.device_get(pulls)
        done, active = got[0], got[1]
        if self.spec_k:
            self._record_spec(got[2], got[3], got[4])
        rows = self.scheduler.finished_rows(done, active)
        if not rows:
            return []
        out_host, n_host, fin_host, gen_host, lp_host = jax.device_get(
            (self.state["out_buf"], self.state["n_out"],
             self.state["finish"], self.state["gen_step"],
             self.state["logprob_buf"]))
        for i in rows:
            if int(gen_host[i]) >= 0:
                # gen_step is the 0-based index of the advance_slots call
                # that produced the token; it exists once that call ends
                self.scheduler.slots[i].first_token_time = self._step_time(
                    int(gen_host[i]) + 1)
            self._prefill_left[i] = 0
        self.state, comps = self.scheduler.retire(
            self.state, rows, out_host, n_host, fin_host, lp_host)
        return comps

    def _record_spec(self, hist, drafted, emitted):
        """Emit speculative acceptance metrics as deltas against the
        host mirror of the device-side running totals (pure host
        arithmetic over values the one per-step sync already pulled)."""
        mets = self.metrics
        prev_hist, prev_drafted, prev_emitted = self._spec_prev
        hist = [int(x) for x in hist]
        drafted, emitted = int(drafted), int(emitted)
        if mets.enabled:
            mets.counter("serve_spec_draft_tokens_total").inc(
                drafted - prev_drafted)
            mets.counter("serve_spec_emitted_tokens_total").inc(
                emitted - prev_emitted)
            h = mets.histogram("serve_spec_accepted_len",
                               {"spec_k": self.spec_k},
                               buckets=self._spec_buckets)
            for n, (c, pc) in enumerate(zip(hist, prev_hist)):
                for _ in range(c - pc):
                    h.observe(float(n))
            rounds = sum(hist)
            if drafted > 0:
                # accepted drafts = emitted tokens minus the one
                # boundary/bonus token every decode round emits
                mets.gauge("serve_spec_accept_rate").set(
                    (emitted - rounds) / drafted)
        self._spec_prev = (hist, drafted, emitted)

    def run(self, substeps: int = 1, max_steps: int | None = None):
        """Drive ``step()`` until all submitted work is finished; returns
        {rid: Completion}. ``max_steps`` bounds the total number of decode
        steps: the final call's substeps are clamped to the remaining
        budget, so ``max_steps=4, substeps=8`` runs exactly 4 steps."""
        out = {}
        limit = max_steps if max_steps is not None else 10_000_000
        while self.has_work() and limit > 0:
            n = min(substeps, limit)
            for c in self.step(substeps=n):
                out[c.rid] = c
            limit -= n
        return out

    # -- legacy API ----------------------------------------------------

    def generate(self, prompts: list, max_new_tokens: int = 16,
                 enc_out=None, sampling: SamplingParams | None = None,
                 eos_token: int | None = None) -> list:
        """Old lockstep-engine API: greedy-decode ``max_new_tokens`` for
        each prompt, outputs in submission order. Now a thin wrapper over
        the continuous-batching scheduler (prompt counts beyond
        ``batch_size`` simply queue)."""
        if enc_out is not None:
            if self.scheduler.has_work():
                raise ValueError("enc_out requires an idle engine "
                                 "(rows map to slots in submission order)")
            if enc_out.shape[0] != self.batch_size:
                raise ValueError(
                    f"enc_out has {enc_out.shape[0]} rows but the engine "
                    f"has {self.batch_size} slots; slot i reads encoder "
                    f"row i, so they must match")
            if len(prompts) > self.batch_size:
                raise ValueError("enc_out rows cannot exceed batch_size")
            self.enc_out = enc_out
            self._enc_submits = 0   # idle engine: slots refill from 0
        rids = [self.submit(p, max_new_tokens=max_new_tokens,
                            sampling=sampling, eos_token=eos_token)
                for p in prompts]
        comps = self.run()
        return [comps[r].tokens for r in rids]
