"""Speculative decoding with logit-free CCE verification (DESIGN.md §12).

One speculative round per engine step: a drafter proposes up to K tokens
per decode row, the target model runs the window ``[t0, d1 .. dK]``
through ONE multi-token forward (``transformer.serve_prefill_spec`` —
the chunked-prefill machinery every mixer family already supports), and
every position is scored by ONE fused projection->sample sweep
(``kernels.decode_sample`` via ``sampling.verify_tokens_fused``). The
sweep returns, per position, the greedy/sampled pick, its logprob, and
the target logprob of the *next* window token (the draft) — so the
standard speculative-sampling ratio test runs without ever
materializing ``(B, K, V)`` logits, and a rejection's bonus token is
drawn from the residual ``max(p - q, 0)`` by the same online-LSE +
Gumbel machinery with the rejected draft excluded from the pick.

Everything in this module is a pure jittable function: the engine calls
these inside its single per-step jit, the per-row accepted lengths are
just another ragged ``advance_slots``-style advance (PRNG pre-advance
per consumed token, as chunked prefill established), and the one host
sync per step is untouched — no ``jax.device_get``, no
``block_until_ready``, nothing host-side lives here.

Drafters
--------

* ``ngram_drafts`` — zero-cost prompt-lookup: find the most recent
  earlier occurrence of the row's current token in its (prompt + output)
  history and propose the K tokens that followed it. Stateless,
  device-side, no extra parameters.
* a small draft transformer (any config sharing the vocab) — the engine
  owns its cache; ``draft_catchup`` folds the window each row consumed
  last round into the draft cache (masked per-row commit via
  ``transformer.select_cache_rows``) and ``draft_propose`` rolls K
  greedy one-token steps on a throwaway fork, so the committed draft
  cache never contains an un-consumed position (recurrent states are
  write-once per position).

Rollback semantics
------------------

Rejected draft tokens' KV writes never need undoing for pure-attention
caches: position ``j`` of the next round's window only ever attends
keys at positions ``<= cache_index + j``, all of which are rewritten by
that round's own forward or were committed earlier — stale tail writes
past the committed length are dead by construction, paged or dense, and
the kvpool's host-side page tables and refcounts are untouched by a
fully-rejected round. Recurrent (RG-LRU, RWKV-6) and SWA-ring caches do
carry state across the rejected tail, so the engine replays the window
prefix: a second ``lm_hidden`` pass over the *original* cache with
``valid_len = commit_len``, i.e. the masked re-write the ISSUE calls
for (see ``needs_replay``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.serve import sampling as S
from repro.serve.scheduler import NO_EOS

# fold_in salt separating the acceptance-test uniform from the sample
# key it derives from (the key itself already went into the Gumbel hash)
_ACCEPT_SALT = 0x5BEC

# cache kinds whose state at position i depends on writes at positions
# < i (ring pointers / recurrent accumulators): a rejected tail corrupts
# them, so the engine must replay the committed prefix on the original
# cache. Pure "attn" caches are position-addressed and self-healing.
_REPLAY_KINDS = frozenset({"swa", "rglru", "rwkv6"})


def needs_replay(cfg) -> bool:
    """Static (host-side, trace-time) arch test: does a speculative
    round need the commit-by-replay pass (see module docstring)?"""
    return bool(set(cfg.pattern_for(cfg.num_layers)) & _REPLAY_KINDS)


def ngram_drafts(state, spec_k: int):
    """Prompt-lookup drafter: (B, spec_k) int32 proposals, device-side.

    Per row, the generated history is ``prompt ++ out_buf[:n_out]`` and
    the current token ``state["tok"]`` is its last element (the decode
    invariant: ``tok`` is the most recently emitted token). Find the
    most recent *earlier* occurrence of that token and propose the
    ``spec_k`` tokens that followed it; rows with no match propose
    token 0 (they will simply be rejected by verification).
    """
    b, p_cap = state["prompt_buf"].shape
    m = state["out_buf"].shape[1]
    L = p_cap + m
    j = jnp.arange(L)[None, :]                              # (1, L)
    plen = state["prompt_len"][:, None]                     # (B, 1)
    seq = jnp.where(
        j < plen,
        jnp.take_along_axis(
            state["prompt_buf"], jnp.clip(j, 0, p_cap - 1), axis=1),
        jnp.take_along_axis(
            state["out_buf"], jnp.clip(j - plen, 0, m - 1), axis=1))
    last = state["prompt_len"] + state["n_out"] - 1         # (B,)
    tok = state["tok"]                                      # (B, 1)
    hit = (j < last[:, None]) & (seq == tok)
    match = jnp.max(jnp.where(hit, j, -1), axis=1)          # (B,)
    off = jnp.arange(spec_k)[None, :]                       # (1, K)
    # continuation positions past the known history clamp to the last
    # known token (copying unknown future would propose buffer zeros);
    # a wrong guess just gets rejected by verification
    src = jnp.clip(jnp.minimum(match[:, None] + 1 + off, last[:, None]),
                   0, L - 1)
    drafts = jnp.take_along_axis(seq, src, axis=1)
    return jnp.where(match[:, None] >= 0, drafts, 0).astype(jnp.int32)


def build_windows(state, drafts, *, spec_k: int, max_len: int):
    """Assemble the per-row verification window and its shape metadata.

    Returns ``(window (B, S), n_tok (B,), in_prompt (B,), k_b (B,))``
    with ``S = spec_k + 1``:

    * prefill rows consume the next ``n_tok = min(S, prompt_len - p)``
      prompt tokens (speculation subsumes chunked prefill — one jit);
    * decode rows consume ``[tok, d1 .. d_{k_b}]`` where
      ``k_b = min(spec_k, rem - 1, max_len - 1 - p)`` caps the offered
      drafts so every emitted token stays inside the row's ``max_new``
      budget and its reserved cache span (``rem = max_new - n_out``);
      ``k_b = 0`` degenerates to the plain single-token step;
    * dead rows consume their frozen ``tok`` once, like the plain step.
    """
    s = spec_k + 1
    p = state["cache_index"]
    live = state["active"] & ~state["done"]
    in_prompt = live & (p < state["prompt_len"])
    p_cap = state["prompt_buf"].shape[1]

    idx = jnp.clip(p[:, None] + jnp.arange(s)[None, :], 0, p_cap - 1)
    ptoks = jnp.take_along_axis(state["prompt_buf"], idx, axis=1)
    dwindow = jnp.concatenate(
        [state["tok"], drafts[:, : s - 1]], axis=1)
    window = jnp.where(in_prompt[:, None], ptoks, dwindow)

    rem = state["max_new"] - state["n_out"]
    k_b = jnp.minimum(jnp.asarray(spec_k, jnp.int32),
                      jnp.minimum(rem - 1, max_len - 1 - p))
    k_b = jnp.where(live & ~in_prompt, jnp.clip(k_b, 0, spec_k), 0)
    n_tok = jnp.where(
        in_prompt,
        jnp.minimum(jnp.asarray(s, jnp.int32), state["prompt_len"] - p),
        1 + k_b)
    n_tok = jnp.where(live, n_tok, 1).astype(jnp.int32)
    return window.astype(jnp.int32), n_tok, in_prompt, k_b


def verify_labels(window, n_tok):
    """Per-position ``(labels, exclude)`` for the fused sweep.

    Position ``j`` predicts window token ``j + 1``: its label is the
    draft to be ratio-tested there, and — only while a successor
    actually exists (``j < n_tok - 1``) — that same token is excluded
    from the position's *sampled* pick so a rejection bonus draws from
    the residual support. The last valid position (prefill boundary
    sample, or the all-accepted bonus) keeps the full support
    (``exclude = -1``).
    """
    s = window.shape[1]
    nxt = jnp.roll(window, -1, axis=1)          # nxt[:, j] = window[:, j+1]
    j = jnp.arange(s)[None, :]
    exclude = jnp.where(j < (n_tok - 1)[:, None], nxt, -1)
    return nxt.astype(jnp.int32), exclude.astype(jnp.int32)


def run_verify_sweep(params, cfg, hidden, window, n_tok, keys, state, *,
                     with_filter: bool, with_sample: bool):
    """Score every window position with ONE fused decode sweep.

    ``hidden``: (B, S, D) from ``serve_prefill_spec``; ``keys``:
    (B, S, 2) per-position sample keys (``scheduler.sample_keys_all`` —
    position ``j`` uses the key the ``(j+1)``-th one-token step would
    have, so the prefill boundary sample bit-matches the plain engine).
    Returns ``(tok, lp, label_lp)`` each (B, S).
    """
    b, s, d = hidden.shape
    labels, exclude = verify_labels(window, n_tok)
    rep = lambda v: jnp.repeat(v, s)            # row params -> positions
    tok, lp, label_lp = S.verify_tokens_fused(
        hidden.reshape(b * s, d),
        T.classifier_matrix(params, cfg),
        keys.reshape(b * s, 2),
        rep(state["temperature"]), rep(state["top_k"]),
        rep(state["top_p"]),
        labels=labels.reshape(b * s), exclude=exclude.reshape(b * s),
        vocab=cfg.vocab_size, softcap=cfg.logit_softcap,
        with_filter=with_filter, with_sample=with_sample)
    return (tok.reshape(b, s).astype(jnp.int32), lp.reshape(b, s),
            label_lp.reshape(b, s))


def accept_and_advance(state, window, n_tok, in_prompt, k_b, tok_s, lp_s,
                       label_lp, keys, carries, *, spec_k: int,
                       max_len: int):
    """The ragged multi-token slot-state transition.

    Mirrors ``scheduler.advance_slots`` exactly at ``k_b = 0`` and
    extends it to per-row accepted lengths: greedy rows accept draft
    ``d_{j+1}`` iff it equals position ``j``'s argmax (exact-match);
    sampled rows accept iff ``u_j < p(d_{j+1})`` (the ratio test with a
    deterministic drafter, ``q = 1``), with ``u_j`` derived from the
    position's own sample key. The emitted stream is the accepted
    prefix plus the bonus pick at the first rejection (or the boundary
    sample for prefill rows), truncated at EOS; stop flags, ``finish``
    priority, ``gen_step``/TTFT attribution, PRNG advance-per-consumed-
    token and the frozen-when-done discipline all match the plain path.

    Returns ``(new_state, commit_len, advanced)``: ``commit_len (B,)``
    in [1, S] is how many window positions are now committed cache
    content (the replay ``valid_len``), ``advanced (B,)`` marks rows
    whose cache_index moved (the draft catch-up set).
    """
    b, m = state["out_buf"].shape
    s = spec_k + 1
    rows = jnp.arange(b)
    j = jnp.arange(s)[None, :]
    live = state["active"] & ~state["done"]
    p = state["cache_index"]

    # -- acceptance: leading run of accepted drafts ---------------------
    u = jax.vmap(
        lambda k: jax.random.uniform(jax.random.fold_in(k, _ACCEPT_SALT))
    )(keys.reshape(b * s, 2)).reshape(b, s)
    nxt = jnp.roll(window, -1, axis=1)          # draft tested at pos j
    ok_greedy = tok_s == nxt
    ok_sampled = u < jnp.exp(label_lp)
    greedy_row = state["temperature"] <= 0.0
    ok = jnp.where(greedy_row[:, None], ok_greedy, ok_sampled)
    ok = ok & (j < k_b[:, None])
    lead = jnp.cumprod(ok.astype(jnp.int32), axis=1)
    a = jnp.sum(lead, axis=1)                   # accepted drafts, <= k_b

    # -- emitted stream -------------------------------------------------
    # decode rows: accepted drafts then the bonus pick at position a;
    # prefill rows: the boundary sample (position n_tok - 1), if the
    # prompt is exhausted this step
    em_tok = jnp.where(j < a[:, None], nxt, tok_s)
    em_lp = jnp.where(j < a[:, None], label_lp, lp_s)
    bsel = (n_tok - 1)[:, None]
    bt = jnp.take_along_axis(tok_s, bsel, axis=1)
    blp = jnp.take_along_axis(lp_s, bsel, axis=1)
    stream_tok = jnp.where(in_prompt[:, None],
                           jnp.broadcast_to(bt, (b, s)), em_tok)
    stream_lp = jnp.where(in_prompt[:, None],
                          jnp.broadcast_to(blp, (b, s)), em_lp)
    crossed = in_prompt & (p + n_tok >= state["prompt_len"])
    raw_cnt = jnp.where(
        live, jnp.where(in_prompt, jnp.where(crossed, 1, 0), a + 1), 0)

    # EOS truncates the stream sequentially: tokens past the first EOS
    # were never emitted (and their window positions never consumed)
    has_eos = state["eos"] != NO_EOS
    is_eos = has_eos[:, None] & (stream_tok == state["eos"][:, None])
    in_stream = j < raw_cnt[:, None]
    eos_pos = jnp.min(jnp.where(is_eos & in_stream, j, s), axis=1)
    hit_eos = eos_pos < raw_cnt
    n_emit = jnp.where(hit_eos, eos_pos + 1, raw_cnt)

    # -- record ---------------------------------------------------------
    slots = state["n_out"][:, None] + j
    wslot = jnp.where(j < n_emit[:, None], slots, m)    # m = dropped
    out_buf = state["out_buf"].at[rows[:, None], wslot].set(
        stream_tok, mode="drop")
    logprob_buf = state["logprob_buf"].at[rows[:, None], wslot].set(
        stream_lp, mode="drop")
    n_out = state["n_out"] + n_emit
    gen = n_emit > 0

    # -- stop flags (plain-path priority: eos > length > cache) ---------
    # consumed positions this round: full prompt chunk for prefill rows,
    # one per emitted token for decode rows (EOS stops consumption), one
    # for dead rows (the plain step's unconditional PRNG tick)
    n_cons = jnp.where(live, jnp.where(in_prompt, n_tok, n_emit), 1)
    nxt_pos = p + n_cons
    hit_len = gen & (n_out >= state["max_new"])
    hit_cap = live & (nxt_pos >= max_len)
    done = state["done"] | hit_eos | hit_len | hit_cap

    # -- advance --------------------------------------------------------
    advance = live & ~done
    p_cap = state["prompt_buf"].shape[1]
    prompt_next = jnp.take_along_axis(
        state["prompt_buf"], jnp.clip(nxt_pos, 0, p_cap - 1)[:, None],
        axis=1)[:, 0]
    last_emit = jnp.take_along_axis(
        stream_tok, jnp.clip(n_emit - 1, 0, s - 1)[:, None], axis=1)[:, 0]
    next_tok = jnp.where(nxt_pos < state["prompt_len"], prompt_next,
                         last_emit)
    rng = jnp.take_along_axis(
        carries, jnp.clip(n_cons, 0, s)[:, None, None], axis=1)[:, 0]

    # committed window prefix (replay valid_len, in [1, S]) and the
    # catch-up record for the draft model: rows that advanced consumed
    # n_cons window tokens; everyone else contributes nothing
    commit_len = jnp.clip(jnp.where(live, n_cons, 1), 1, s)
    spec_n = jnp.where(advance, n_cons, 0).astype(jnp.int32)

    new_state = dict(
        state,
        tok=jnp.where(advance[:, None], next_tok[:, None], state["tok"]),
        cache_index=jnp.where(advance, nxt_pos, p),
        done=done,
        out_buf=out_buf,
        logprob_buf=logprob_buf,
        n_out=n_out,
        rng=rng,
        finish=jnp.where(
            state["finish"] > 0, state["finish"],
            jnp.where(hit_eos, 1, jnp.where(hit_len, 2,
                      jnp.where(hit_cap, 3, 0)))),
        gen_step=jnp.where(gen & (state["gen_step"] < 0), state["t"],
                           state["gen_step"]),
        t=state["t"] + 1,
    )
    if "spec_src" in state:
        dec = live & ~in_prompt
        hist_idx = jnp.where(dec, jnp.clip(n_emit, 0, s), 0)
        new_state["spec_src"] = window
        new_state["spec_n"] = spec_n
        new_state["spec_hist"] = state["spec_hist"].at[hist_idx].add(
            dec.astype(jnp.int32))
        new_state["spec_drafted"] = (
            state["spec_drafted"] + jnp.sum(jnp.where(dec, k_b, 0)))
        new_state["spec_emitted"] = (
            state["spec_emitted"] + jnp.sum(jnp.where(dec, n_emit, 0)))
    return new_state, commit_len, advance


# -- draft-transformer drafter ----------------------------------------


def draft_catchup(draft_params, draft_cfg, draft_cache, state):
    """Fold last round's consumed window into the draft cache.

    ``state["spec_src"]``/``state["spec_n"]`` record what each row
    actually committed; the catch-up forward ingests exactly that
    prefix at the positions it occupied (``cache_index - spec_n ..
    cache_index - 1``) and ``select_cache_rows`` commits it only for
    rows that advanced — so the draft cache tracks the target cache
    position-for-position, one round behind, and never contains the
    current un-consumed token.
    """
    ci0 = state["cache_index"] - state["spec_n"]
    vl = jnp.maximum(state["spec_n"], 1)
    _, new_cache, _ = T.lm_hidden(
        draft_params, draft_cfg, {"tokens": state["spec_src"]},
        cache=draft_cache, cache_index=ci0, valid_len=vl)
    return T.select_cache_rows(state["spec_n"] > 0, new_cache,
                               draft_cache)


def draft_propose(draft_params, draft_cfg, draft_cache, state,
                  spec_k: int):
    """K greedy one-token draft steps on a throwaway cache fork.

    The first step consumes the row's current token at its live
    position; each subsequent step consumes the previous proposal. The
    fork is discarded — the committed draft cache is only ever advanced
    by :func:`draft_catchup` over tokens the target actually consumed.
    Returns drafts (B, spec_k) int32.
    """
    b = state["tok"].shape[0]
    fork = draft_cache
    tok = state["tok"]
    ci = state["cache_index"]
    # greedy via the fused projection->sample path: the draft's (B, V)
    # logits never materialize either (keys are unused when every row
    # routes greedy, so the row PRNG state is a harmless placeholder)
    sample = (state["rng"], jnp.zeros((b,), jnp.float32),
              jnp.zeros((b,), jnp.int32), jnp.ones((b,), jnp.float32))
    drafts = []
    for step in range(spec_k):      # static unroll: spec_k is a jit const
        (nxt, _), fork = T.serve_step(
            draft_params, draft_cfg, fork, tok, ci + step,
            return_logits=False, sample=sample, with_filter=False,
            with_sample=False)
        nxt = nxt.astype(jnp.int32)
        drafts.append(nxt)
        tok = nxt[:, None]
    if not drafts:
        return jnp.zeros((b, 0), jnp.int32)
    return jnp.stack(drafts, axis=1)
