"""Serving: batched greedy decode engine over serve_step."""
from repro.serve.engine import Engine  # noqa: F401
