"""Serving subsystem: continuous-batching decode + CCE-backed scoring.

  * :class:`~repro.serve.engine.Engine` — slot-based continuous batching;
    one jitted step does model forward (per-row ``cache_index``),
    device-side sampling, and EOS/length stopping; one host sync per step.
  * :mod:`repro.serve.sampling` — greedy / temperature / top-k / top-p
    with per-request parameters, all on device.
  * :mod:`repro.serve.scheduler` — request queue, slot recycling,
    the pure slot-state transition.
  * :mod:`repro.serve.scoring` — ``score(prompt, completions)`` lowered
    through ``cross_entropy(..., loss="seq_logprob")``: O(B·S·D + V·D)
    memory, never (B, S, V) logits.
  * :mod:`repro.serve.kvpool` — block-paged KV allocator (free list,
    refcounts, prefix registry) behind ``Engine(kv_page_size=...)``:
    per-slot page tables replace dense per-slot KV rows, and page-aligned
    shared prompt prefixes are reused copy-free across requests.
  * :mod:`repro.serve.speculative` — draft/verify decoding behind
    ``Engine(spec_k=...)``: a zero-cost n-gram drafter (or a small draft
    transformer via ``draft_cfg``/``draft_params``) proposes up to K
    tokens, one multi-token forward plus one fused CCE sweep verifies
    them without ``(B, K, V)`` logits, and each step emits up to K+1
    tokens for the same single host sync.
"""
from repro.serve.engine import Engine  # noqa: F401
from repro.serve.kvpool import KVPool  # noqa: F401
from repro.serve.sampling import GREEDY, SamplingParams  # noqa: F401
from repro.serve.scheduler import Completion, Request, Scheduler  # noqa: F401
from repro.serve.scoring import rank, score, token_logprobs  # noqa: F401
from repro.serve.speculative import needs_replay, ngram_drafts  # noqa: F401
