"""Pallas TPU kernels for Cut Cross-Entropy (the paper's compute hot-spot).

Layout per repo convention:
  cce_fwd.py / cce_bwd.py / indexed_matmul.py — pl.pallas_call kernels with
      explicit BlockSpec VMEM tiling (TPU target; interpret=True on CPU).
  ops.py — jit'd differentiable wrappers + block-size heuristics.
  ref.py — pure-jnp oracles the kernels are tested against.
"""

from repro.kernels.ops import (  # noqa: F401
    CCEConfig,
    choose_blocks,
    kernel_plan,
    linear_cross_entropy_pallas,
    live_block_bitmap,
    lse_and_pick_pallas,
    lse_pick_sum_pallas,
    vmem_working_set,
)
from repro.kernels.indexed_matmul import indexed_matmul_pallas  # noqa: F401
# NOTE: the dispatcher function `decode_sample.decode_sample` is *not*
# re-exported here — it would shadow the submodule attribute of the same
# name. Import it from the submodule.
from repro.kernels.decode_sample import (  # noqa: F401
    choose_decode_blocks,
    decode_sample_pallas,
    decode_sample_ref,
    decode_vmem_working_set,
)
from repro.kernels.ref import IGNORE_INDEX  # noqa: F401
