"""Pallas-TPU forward kernel for Cut Cross-Entropy (paper Alg. 1 + 2, fused).

One kernel computes, for every token i:
  * ``lse_i  = log sum_v exp(softcap(C_v . E_i))``   (linear-log-sum-exp)
  * ``pick_i = softcap(C[x_i] . E_i)``               (indexed matmul)
  * ``sum_i  = sum_v softcap(C_v . E_i)``            (optional, with_sum —
                                                      feeds label smoothing
                                                      in repro.losses)

so that ``nll_i = lse_i - pick_i``. The ``(N, V)`` logit matrix only ever
exists one ``(block_n, block_v)`` tile at a time, in VMEM.

TPU adaptation vs. the paper's Triton kernel (see DESIGN.md §2):
  * The grid is *sequential* over the vocabulary axis (innermost,
    ``dimension_semantics=("parallel", "arbitrary")``). The online LSE is
    carried in VMEM scratch across vocab steps — no global-memory spin-lock
    atomics, which TPUs do not have (and do not need here).
  * The label logit is extracted with a broadcasted-iota column mask fused
    into the same tile (VPU-friendly), not a dynamic gather.
  * f32 accumulation in VMEM regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _util
from repro.kernels._util import sds


def _fwd_kernel(x_ref, e_ref, c_ref, *refs,
                softcap, n_tokens, vocab, block_n, block_v, with_sum):
    if with_sum:
        lse_ref, pick_ref, sum_ref, m_acc, s_acc, p_acc, z_acc = refs
    else:
        lse_ref, pick_ref, m_acc, s_acc, p_acc = refs
        sum_ref = z_acc = None
    v = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(v == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, -jnp.inf)
        s_acc[...] = jnp.zeros_like(s_acc)
        p_acc[...] = jnp.zeros_like(p_acc)
        if with_sum:
            z_acc[...] = jnp.zeros_like(z_acc)

    e = e_ref[...].astype(jnp.float32)  # (block_n, D)
    c = c_ref[...].astype(jnp.float32)  # (block_v, D)
    # (block_n, block_v) logit tile — lives only in VMEM.
    a = jax.lax.dot_general(e, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap is not None:
        a = softcap * jnp.tanh(a / softcap)

    col = v * block_v + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    if with_sum:
        # sum of (capped) logits over the real vocabulary — third output,
        # e.g. the label-smoothing uniform term. Padded columns add 0 (the
        # -inf mask below would poison the sum).
        z_acc[...] += jnp.sum(jnp.where(col < vocab, a, 0.0),
                              axis=1, keepdims=True)
    a = jnp.where(col < vocab, a, -jnp.inf)  # mask padded vocab columns

    labels = x_ref[...]  # (block_n, 1) int32
    pick_mask = col == labels  # each label matches exactly one column overall
    p_acc[...] += jnp.sum(jnp.where(pick_mask, a, 0.0), axis=1, keepdims=True)

    # Online (streaming) log-sum-exp, numerically stable.
    bmax = jnp.max(a, axis=1, keepdims=True)
    m_new = jnp.maximum(m_acc[...], bmax)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    s_acc[...] = (s_acc[...] * jnp.exp(m_acc[...] - m_safe)
                  + jnp.sum(jnp.exp(a - m_safe), axis=1, keepdims=True))
    m_acc[...] = m_new

    @pl.when(v == nv - 1)
    def _finalize():
        lse_ref[...] = m_acc[...] + jnp.log(s_acc[...])
        pick_ref[...] = p_acc[...]
        if with_sum:
            sum_ref[...] = z_acc[...]


def cce_forward_pallas(E: jax.Array, C: jax.Array, x: jax.Array, *,
                       softcap: float | None = None,
                       block_n: int = 128, block_v: int = 256,
                       with_sum: bool = False,
                       interpret: bool = False):
    """Returns ``(lse, pick)`` — or ``(lse, pick, sum_logits)`` when
    ``with_sum`` — as f32 ``(N,)`` vectors.

    E: (N, D), C: (V, D), x: (N,) int32 with labels already clamped to
    [0, V) (ignored positions are handled by the caller via the upstream
    gradient / loss mask — the kernel itself is label-agnostic).

    ``with_sum`` is static: when False the sum accumulator and its output
    are not part of the kernel at all (no dead compute).
    """
    n_tokens, d = E.shape
    vocab, d2 = C.shape
    assert d == d2, (E.shape, C.shape)
    assert x.shape == (n_tokens,)

    grid = (pl.cdiv(n_tokens, block_n), pl.cdiv(vocab, block_v))
    x2 = x.astype(jnp.int32).reshape(n_tokens, 1)

    kernel = functools.partial(
        _fwd_kernel, softcap=softcap, n_tokens=n_tokens, vocab=vocab,
        block_n=block_n, block_v=block_v, with_sum=with_sum)

    n_out = 3 if with_sum else 2
    out_spec = pl.BlockSpec((block_n, 1), lambda n, v: (n, 0))
    scratch = [pltpu.VMEM((block_n, 1), jnp.float32)  # max / sum-exp /
               for _ in range(n_out + 1)]             # pick / (sum-logits)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda n, v: (n, 0)),   # labels
            pl.BlockSpec((block_n, d), lambda n, v: (n, 0)),   # E
            pl.BlockSpec((block_v, d), lambda n, v: (v, 0)),   # C
        ],
        out_specs=[out_spec] * n_out,
        out_shape=[sds((n_tokens, 1), jnp.float32, x2, E, C)] * n_out,
        scratch_shapes=scratch,
        compiler_params=_util.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x2, E, C)
    return tuple(o[:, 0] for o in outs)
