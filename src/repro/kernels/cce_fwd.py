"""Pallas-TPU forward kernel for Cut Cross-Entropy (paper Alg. 1 + 2, fused).

One kernel computes, for every token i:
  * ``lse_i  = log sum_v exp(softcap(C_v . E_i))``   (linear-log-sum-exp)
  * ``pick_i = softcap(C[x_i] . E_i)``               (indexed matmul)
  * ``sum_i  = sum_v softcap(C_v . E_i)``            (optional, with_sum —
                                                      feeds label smoothing
                                                      in repro.losses)

so that ``nll_i = lse_i - pick_i``. The ``(N, V)`` logit matrix only ever
exists one ``(block_n, block_v)`` tile at a time, in VMEM.

TPU adaptation vs. the paper's Triton kernel (see DESIGN.md §2):
  * The grid is *sequential* over the vocabulary axis (innermost,
    ``dimension_semantics=("parallel", "arbitrary")``). The online LSE is
    carried in VMEM scratch across vocab steps — no global-memory spin-lock
    atomics, which TPUs do not have (and do not need here).
  * The label logit is extracted with a broadcasted-iota column mask fused
    into the same tile (VPU-friendly), not a dynamic gather.
  * f32 accumulation in VMEM regardless of input dtype.

Forward-emitted block-sparsity map (DESIGN.md §7): with ``emit_bitmap`` the
kernel additionally returns a per-``(n_block, v_block)`` **live-block
bitmap** — the gradient-filtering decision of paper §4.3 precomputed while
the logit tile is already in VMEM. A block is *live* iff any of its valid
rows has ``max_j a[i, j] - lse_i >= log(eps)`` (equivalently
``max_j S[i, j] >= eps``) or contains a row's label (label blocks are
always live, so the one-hot term can never be filtered). The per-row
per-v-block tile maxima are staged in one extra VMEM scratch column per
vocab step and reduced against the online LSE at the final step, so the
bitmap costs no extra pass over the vocabulary. Both backward passes (and
the fused single-pass backward) can then ``@pl.when``-skip the logit-tile
*recompute itself* on dead blocks, instead of recomputing the tile only to
discover the block was filterable. The bitmap is
O(N·V / (block_n·block_v)) int32 — negligible next to E and C.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _util
from repro.kernels._util import sds


def _fwd_kernel(x_ref, e_ref, c_ref, *refs,
                softcap, n_tokens, vocab, block_n, block_v, with_sum,
                emit_bitmap, filter_eps):
    refs = list(refs)
    n_out = (3 if with_sum else 2) + (1 if emit_bitmap else 0)
    out_refs, scr = refs[:n_out], refs[n_out:]
    if with_sum:
        lse_ref, pick_ref, sum_ref = out_refs[:3]
        m_acc, s_acc, p_acc, z_acc = scr[:4]
        scr = scr[4:]
    else:
        lse_ref, pick_ref = out_refs[:2]
        m_acc, s_acc, p_acc = scr[:3]
        sum_ref = z_acc = None
        scr = scr[3:]
    bm_ref = out_refs[-1] if emit_bitmap else None
    rm_acc = scr[0] if emit_bitmap else None
    v = pl.program_id(1)
    nv = pl.num_programs(1)
    n = pl.program_id(0)

    @pl.when(v == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, -jnp.inf)
        s_acc[...] = jnp.zeros_like(s_acc)
        p_acc[...] = jnp.zeros_like(p_acc)
        if with_sum:
            z_acc[...] = jnp.zeros_like(z_acc)

    e = e_ref[...].astype(jnp.float32)  # (block_n, D)
    c = c_ref[...].astype(jnp.float32)  # (block_v, D)
    # (block_n, block_v) logit tile — lives only in VMEM.
    a = jax.lax.dot_general(e, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap is not None:
        a = softcap * jnp.tanh(a / softcap)

    col = v * block_v + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    if with_sum:
        # sum of (capped) logits over the real vocabulary — third output,
        # e.g. the label-smoothing uniform term. Padded columns add 0 (the
        # -inf mask below would poison the sum).
        z_acc[...] += jnp.sum(jnp.where(col < vocab, a, 0.0),
                              axis=1, keepdims=True)
    a = jnp.where(col < vocab, a, -jnp.inf)  # mask padded vocab columns

    labels = x_ref[...]  # (block_n, 1) int32
    pick_mask = col == labels  # each label matches exactly one column overall
    p_acc[...] += jnp.sum(jnp.where(pick_mask, a, 0.0), axis=1, keepdims=True)

    # Online (streaming) log-sum-exp, numerically stable.
    bmax = jnp.max(a, axis=1, keepdims=True)
    if emit_bitmap:
        # Stage this v-block's per-row tile max; the block-liveness decision
        # needs the final LSE and is taken once, in _finalize.
        rm_acc[:, pl.ds(v, 1)] = bmax
    m_new = jnp.maximum(m_acc[...], bmax)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    s_acc[...] = (s_acc[...] * jnp.exp(m_acc[...] - m_safe)
                  + jnp.sum(jnp.exp(a - m_safe), axis=1, keepdims=True))
    m_acc[...] = m_new

    @pl.when(v == nv - 1)
    def _finalize():
        lse = m_acc[...] + jnp.log(s_acc[...])
        lse_ref[...] = lse
        pick_ref[...] = p_acc[...]
        if with_sum:
            sum_ref[...] = z_acc[...]
        if emit_bitmap:
            # live[b] = any valid row with max_j S[i, j] >= eps, or any valid
            # row whose label lands in block b (one-hot gradients are never
            # filterable). Padded rows (ragged N edge) carry undefined tile
            # maxima and labels — masked out via the row index.
            score = rm_acc[...] - lse                    # (block_n, nv)
            vb = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
            rows = (n * block_n
                    + jax.lax.broadcasted_iota(jnp.int32, score.shape, 0))
            live = (score >= jnp.log(filter_eps)) | (vb == labels // block_v)
            live &= rows < n_tokens
            bm_ref[...] = jnp.max(live.astype(jnp.int32), axis=0,
                                  keepdims=True)


def cce_forward_pallas(E: jax.Array, C: jax.Array, x: jax.Array, *,
                       softcap: float | None = None,
                       block_n: int = 128, block_v: int = 256,
                       with_sum: bool = False,
                       emit_bitmap: bool = False,
                       filter_eps: float | None = None,
                       interpret: bool = False):
    """Returns ``(lse, pick)`` — or ``(lse, pick, sum_logits)`` when
    ``with_sum`` — as f32 ``(N,)`` vectors.

    E: (N, D), C: (V, D), x: (N,) int32 with labels already clamped to
    [0, V) (ignored positions are handled by the caller via the upstream
    gradient / loss mask — the kernel itself is label-agnostic).

    ``with_sum`` is static: when False the sum accumulator and its output
    are not part of the kernel at all (no dead compute).

    ``emit_bitmap`` (static) appends a ``(cdiv(N, block_n),
    cdiv(V, block_v))`` int32 live-block bitmap to the outputs: entry
    ``[nb, vb]`` is 1 iff the backward's gradient-filtering statistic at
    threshold ``filter_eps`` could keep the block (see DESIGN.md §7 — a
    conservative superset: label blocks are always live). The backward
    kernels consume it to skip the logit-tile recompute on dead blocks.
    """
    n_tokens, d = E.shape
    vocab, d2 = C.shape
    assert d == d2, (E.shape, C.shape)
    assert x.shape == (n_tokens,)
    if emit_bitmap:
        assert filter_eps is not None and filter_eps > 0.0, filter_eps

    nn, nv = pl.cdiv(n_tokens, block_n), pl.cdiv(vocab, block_v)
    grid = (nn, nv)
    x2 = x.astype(jnp.int32).reshape(n_tokens, 1)

    kernel = functools.partial(
        _fwd_kernel, softcap=softcap, n_tokens=n_tokens, vocab=vocab,
        block_n=block_n, block_v=block_v, with_sum=with_sum,
        emit_bitmap=emit_bitmap, filter_eps=filter_eps)

    n_out = 3 if with_sum else 2
    out_spec = pl.BlockSpec((block_n, 1), lambda n, v: (n, 0))
    out_specs = [out_spec] * n_out
    out_shape = [sds((n_tokens, 1), jnp.float32, x2, E, C)] * n_out
    scratch = [pltpu.VMEM((block_n, 1), jnp.float32)  # max / sum-exp /
               for _ in range(n_out + 1)]             # pick / (sum-logits)
    if emit_bitmap:
        out_specs.append(pl.BlockSpec((1, nv), lambda n, v: (n, 0)))
        out_shape.append(sds((nn, nv), jnp.int32, x2, E, C))
        scratch.append(pltpu.VMEM((block_n, nv), jnp.float32))  # tile maxima
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda n, v: (n, 0)),   # labels
            pl.BlockSpec((block_n, d), lambda n, v: (n, 0)),   # E
            pl.BlockSpec((block_v, d), lambda n, v: (v, 0)),   # C
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_util.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x2, E, C)
    flat = tuple(o[:, 0] for o in outs[:n_out])
    return flat + (outs[n_out],) if emit_bitmap else flat
