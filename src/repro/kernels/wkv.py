"""Pallas-TPU kernel for the RWKV-6 chunked WKV recurrence (forward).

This is the hardware answer to the rwkv6 train_4k roofline finding
(EXPERIMENTS.md §Perf cell B): at the XLA level every per-chunk
intermediate of the chunked recurrence — the decay cumsums, the
stabilized r2/k2 factors, the (L, L) score tile — round-trips HBM between
fusions, leaving the cell ~15x memory-bound. Here the whole chunk
computation lives in VMEM: per grid step the kernel reads the (G, L, hd)
r/k/v/w tiles, carries the (G, hd, hd) state in VMEM scratch across the
*sequential* chunk axis, and writes only the (G, L, hd) output tile.
HBM traffic per chunk is 4 reads + 1 write of L·hd tiles — everything
else (8+ tile-sized intermediates in the scan twin) stays on-chip.

Like the CCE kernels (DESIGN.md §2) the sequential grid axis replaces
what a GPU implementation would do with atomics or grid-sync: the state
hand-off between chunks is a VMEM scratch carried across grid steps with
``dimension_semantics=("parallel", "arbitrary")``.

The backward runs through the pure-jnp twin (``models/recurrent.
_rwkv6_chunk``) via ``jax.custom_vjp`` residual recompute — the paper's
own CCE backward takes the same recompute-over-store stance. The dry-run
intentionally lowers the jnp twin (a Pallas custom call is opaque to
``cost_analysis`` and does not lower on CPU); this kernel is validated in
interpret mode against the sequential oracle (``ref.ref_wkv``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _util
from repro.kernels._util import sds


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, out_ref, sf_ref,
                s_acc, *, nc):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_acc[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)          # (G, L, hd)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # log decay, <= 0
    u = u_ref[...].astype(jnp.float32)          # (G, hd) bonus
    S0 = s_acc[...]                             # (G, hd, hd)

    L = r.shape[1]
    ld = jnp.cumsum(w, axis=1)                  # inclusive within-chunk
    ld_total = ld[:, -1:, :]                    # (G, 1, hd)
    ld_prev = ld - w                            # exclusive
    # stabilized factorization (DESIGN.md §2): exp(ld_prev) <= 1;
    # exp(-ld) clamped — true contribution below e^-60 is zero anyway.
    r2 = r * jnp.exp(ld_prev)
    k2 = k * jnp.exp(-jnp.maximum(ld, -60.0))

    # (G, L, L) score tile — exists only in VMEM.
    att = jax.lax.dot_general(
        r2, k2, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    row = jax.lax.broadcasted_iota(jnp.int32, att.shape, 1)
    col = jax.lax.broadcasted_iota(jnp.int32, att.shape, 2)
    att = jnp.where(col < row, att, 0.0)        # strictly causal

    diag = jnp.sum(r * u[:, None, :] * k, axis=-1)  # (G, L) bonus term
    out = (jax.lax.dot_general(att, v, (((2,), (1,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot_general(r2, S0, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
           + diag[..., None] * v)

    # carry state to the next chunk; k·exp(ld_total - ld) reuses exp(-ld)
    k3 = k2 * jnp.exp(ld_total)
    s_acc[...] = (jnp.exp(ld_total).transpose(0, 2, 1) * S0
                  + jax.lax.dot_general(k3, v, (((1,), (1,)), ((0,), (0,))),
                                        preferred_element_type=jnp.float32))
    out_ref[...] = out.astype(out_ref.dtype)

    @pl.when(c == nc - 1)
    def _final():
        sf_ref[...] = s_acc[...]


def wkv_forward_pallas(r, k, v, w_log, u, state0, *, chunk_len: int = 128,
                       block_g: int = 8, interpret: bool = False):
    """Chunked WKV forward. r/k/v/w_log: (B, H, S, hd); u: (H, hd);
    state0: (B, H, hd, hd) f32. Returns (out (B,H,S,hd) f32,
    final_state (B,H,hd,hd) f32).
    """
    b, h, s, hd = r.shape
    L = min(chunk_len, s)
    assert s % L == 0, (s, L)
    nc = s // L
    bh = b * h
    g = min(block_g, bh)
    assert bh % g == 0, (bh, g)

    def flat(x):
        return x.reshape(bh, s, hd)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w_log)
    u_bh = jnp.broadcast_to(u[None], (b, h, hd)).reshape(bh, hd)
    s0 = state0.reshape(bh, hd, hd)

    grid = (bh // g, nc)
    kernel = functools.partial(_wkv_kernel, nc=nc)
    out, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((g, L, hd), lambda i, c: (i, c, 0)),   # r
            pl.BlockSpec((g, L, hd), lambda i, c: (i, c, 0)),   # k
            pl.BlockSpec((g, L, hd), lambda i, c: (i, c, 0)),   # v
            pl.BlockSpec((g, L, hd), lambda i, c: (i, c, 0)),   # w_log
            pl.BlockSpec((g, hd), lambda i, c: (i, 0)),         # u
            pl.BlockSpec((g, hd, hd), lambda i, c: (i, 0, 0)),  # state0
        ],
        out_specs=[
            pl.BlockSpec((g, L, hd), lambda i, c: (i, c, 0)),   # out
            pl.BlockSpec((g, hd, hd), lambda i, c: (i, 0, 0)),  # final state
        ],
        out_shape=[
            sds((bh, s, hd), jnp.float32, rf, kf, vf, wf),
            sds((bh, hd, hd), jnp.float32, rf, kf, vf, wf),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, hd, hd), jnp.float32),   # carried WKV state
        ],
        compiler_params=_util.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, u_bh, s0)
    return out.reshape(b, h, s, hd), sf.reshape(b, h, hd, hd)


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, jnp-twin recompute backward.
# ---------------------------------------------------------------------------

def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def wkv_apply(r, k, v, w_log, u, state0, chunk_len: int = 128,
              interpret: bool | None = None):
    """(out, final_state) with the kernel forward and a recompute backward
    through the pure-jnp twin (the CCE recompute-over-store stance)."""
    interp = _is_cpu() if interpret is None else interpret
    return wkv_forward_pallas(r, k, v, w_log, u, state0,
                              chunk_len=chunk_len, interpret=interp)


def _wkv_fwd(r, k, v, w_log, u, state0, chunk_len, interpret):
    out = wkv_apply(r, k, v, w_log, u, state0, chunk_len, interpret)
    return out, (r, k, v, w_log, u, state0)


def _wkv_bwd(chunk_len, interpret, res, cots):
    from repro.models.recurrent import _rwkv6_chunk  # jnp twin (no cycle)
    r, k, v, w_log, u, state0 = res

    def twin(r, k, v, w_log, u, state0):
        return _rwkv6_chunk(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w_log, u, state0,
                            chunk_len)

    _, vjp = jax.vjp(twin, r, k, v, w_log, u, state0)
    return vjp(cots)


wkv_apply.defvjp(_wkv_fwd, _wkv_bwd)
