"""Pallas-TPU backward kernels for Cut Cross-Entropy (paper Alg. 3 + 4, fused).

Gradient of ``nll_i = lse_i - pick_i`` w.r.t. the raw logit tile ``a``:

    d nll / d a[i, j] = (S[i, j] - 1[j == x_i]) * g_i,   S = exp(a~ - lse)

where ``a~`` is the (optionally softcapped) logit and ``g`` the upstream
cotangent. The logit tile is *recomputed* in VMEM (never stored), exactly as
in the paper.

TPU adaptation (DESIGN.md §2): the paper's single Triton kernel accumulates
``dE`` and ``dC`` concurrently with global-memory atomics. TPUs have no such
atomics; instead two strategies are provided (``CCEConfig.bwd``):

  * **two_pass** — two sequential-grid passes whose accumulation axis is
    innermost: the ``dE`` pass, grid (n, v) with v innermost, accumulates
    the dE tile in VMEM scratch over vocab blocks (one HBM write per
    n-block); the ``dC`` pass, grid (v, n), is symmetric. Each pass
    recomputes the logit tile, so the (N, V, D) matmul is paid twice.
  * **fused** (DESIGN.md §7) — ONE pass, grid (n, v) with v innermost,
    recomputes each logit tile once and feeds both outgoing matmuls: dE
    accumulates in VMEM scratch exactly as in the dE pass, while dC
    accumulates across the (sequential) n axis directly in its HBM-backed
    output block via read-modify-write — Pallas output windows are
    readable, so a revisited (v) block carries the partial sum. The dC
    output is f32 (cast by the wrapper) so the accumulation is bit-identical
    to the two-pass VMEM scratch: same addends, same order, same dtype.

All variants implement the paper's two throughput tricks:

  * **Gradient filtering**: a block is skipped (``@pl.when``) when every
    entry of the pre-upstream-scaled gradient ``|S - onehot|`` is below
    ``eps`` (default 2^-12, the smallest non-truncated bf16 value — paper
    §4.3). The label's one-hot keeps blocks containing a label from ever
    being filtered. ``filter=False`` reproduces CCE-Kahan-FullC / -FullE.
    The statistic either comes from recomputing the tile (paper Alg. 4,
    ``filter_stats="recompute"`` — the recompute matmul is then paid even
    on dead blocks) or from the forward-emitted live-block ``bitmap``
    (``filter_stats="fwd_bitmap"``, DESIGN.md §7 — dead blocks skip the
    recompute itself).
  * **Vocabulary sorting** is applied by the caller (ops.py) by permuting C
    so hot vocab entries share blocks; the kernels are order-agnostic (the
    caller also re-blocks the bitmap's v axis under the permutation).

Accumulation is f32 in VMEM by default (strictly tighter than the paper's
bf16+Kahan in HBM); ``accum="bf16_kahan"`` reproduces the paper's
compensated-summation variant for the ablation benchmarks (two_pass only —
the fused path is f32-exact by construction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _util
from repro.kernels._util import sds

DEFAULT_FILTER_EPS = 2.0 ** -12


def _zero_padded_rows(tile, start, limit):
    """Zero rows of a (rows, D) tile whose global index >= limit.

    Ragged-edge tiles are padded by Pallas with undefined values (NaN in
    interpret mode); they must not enter any contraction (0*NaN = NaN).
    """
    rows = start + jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
    return jnp.where(rows < limit, tile, 0.0)


def _grad_tile(e, c, labels, lse, g_lse, g_pick, *, softcap, vocab, v_start,
               n_start, n_tokens, g_sum=None):
    """Recompute the logit tile and return (dz, block_live).

    The forward primitive is ``(lse_i, pick_i[, sum_logits_i])``; this tile
    computes the gradient w.r.t. the raw logits for arbitrary upstream
    cotangents:

        dz[i, j] = g_lse_i * S[i, j] + g_pick_i * 1[j == x_i]
                   (+ g_sum_i)                                     (* dcap)

    For the NLL loss (nll = lse - pick) autodiff supplies g_lse = g and
    g_pick = -g, recovering the paper's ``(S - onehot) * g``. The block-skip
    statistic stays the upstream-independent ``max |S - onehot|`` (Alg. 4);
    a non-None ``g_sum`` contributes a *dense* gradient that the statistic
    cannot see, so the caller must disable filtering when passing it.

    Padded rows of e/c (ragged N or V edges) must be zeroed by the caller:
    Pallas pads out-of-bounds tiles with undefined values, and 0*NaN would
    otherwise poison the contraction of the outgoing matmuls.
    """
    a = jax.lax.dot_general(e, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap is not None:
        t = jnp.tanh(a / softcap)
        a_capped = softcap * t
        dcap = 1.0 - t * t  # d a~ / d a
    else:
        a_capped = a
        dcap = None

    col = v_start + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    row = n_start + jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    valid = (col < vocab) & (row < n_tokens)

    s = jnp.exp(a_capped - lse)           # softmax, normalizer-free (paper §4.3)
    s = jnp.where(valid, s, 0.0)
    onehot = jnp.where((col == labels) & valid, 1.0, 0.0)

    live = jnp.max(jnp.abs(s - onehot))   # filter statistic (pre-g, Alg. 4)
    # g rows at a ragged N edge are undefined (NaN) — zero them, or 0*NaN
    # would leak into the dC contraction over rows.
    g_rows = n_start + jax.lax.broadcasted_iota(jnp.int32, g_lse.shape, 0)
    g_lse = jnp.where(g_rows < n_tokens, g_lse, 0.0)
    g_pick = jnp.where(g_rows < n_tokens, g_pick, 0.0)
    dz = g_lse * s + g_pick * onehot      # (block_n, 1) cotangents broadcast
    if g_sum is not None:
        g_sum = jnp.where(g_rows < n_tokens, g_sum, 0.0)
        dz = dz + g_sum * jnp.where(valid, 1.0, 0.0)
    if dcap is not None:
        dz = dz * dcap
    return dz, live


def _accum(acc_ref, comp_ref, contrib, accum_mode):
    """acc += contrib, optionally with Kahan compensation (paper parity)."""
    if accum_mode == "f32":
        acc_ref[...] += contrib
    elif accum_mode == "bf16":
        acc_ref[...] = (acc_ref[...].astype(jnp.bfloat16)
                        + contrib.astype(jnp.bfloat16)).astype(jnp.float32)
    elif accum_mode == "bf16_kahan":
        # Kahan: y = contrib - comp; t = acc + y; comp = (t - acc) - y
        y = contrib.astype(jnp.bfloat16) - comp_ref[...].astype(jnp.bfloat16)
        acc = acc_ref[...].astype(jnp.bfloat16)
        t = acc + y
        comp_ref[...] = ((t - acc) - y).astype(jnp.float32)
        acc_ref[...] = t.astype(jnp.float32)
    else:
        raise ValueError(accum_mode)


def _de_kernel(*refs,
               softcap, vocab, n_tokens, block_n, block_v, filter_eps,
               accum_mode, with_sum=False, with_bitmap=False):
    refs = list(refs)
    bm_ref = refs.pop(0) if with_bitmap else None
    x_ref, gl_ref, gp_ref = refs[:3]
    refs = refs[3:]
    gs_ref = refs.pop(0) if with_sum else None
    lse_ref, e_ref, c_ref, de_ref, acc, comp = refs
    v = pl.program_id(1)
    nv = pl.num_programs(1)
    n = pl.program_id(0)

    @pl.when(v == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        if comp is not None:
            comp[...] = jnp.zeros_like(comp)

    def _tile_and_accum():
        e = _zero_padded_rows(e_ref[...].astype(jnp.float32), n * block_n,
                              n_tokens)
        c = _zero_padded_rows(c_ref[...].astype(jnp.float32), v * block_v,
                              vocab)
        dz, live = _grad_tile(
            e, c, x_ref[...], lse_ref[...], gl_ref[...], gp_ref[...],
            softcap=softcap, vocab=vocab,
            v_start=v * block_v, n_start=n * block_n, n_tokens=n_tokens,
            g_sum=gs_ref[...] if with_sum else None)

        def _mm():
            _accum(acc, comp,
                   jnp.dot(dz, c, preferred_element_type=jnp.float32),
                   accum_mode)

        if filter_eps is not None and not with_bitmap:
            pl.when(live >= filter_eps)(_mm)
        else:
            _mm()

    if with_bitmap:
        # The forward already took the filtering decision — dead blocks skip
        # the logit-tile recompute itself, not just the outgoing matmul.
        pl.when(bm_ref[0, 0] != 0)(_tile_and_accum)
    else:
        _tile_and_accum()

    @pl.when(v == nv - 1)
    def _finalize():
        de_ref[...] = acc[...].astype(de_ref.dtype)


def _dc_kernel(*refs,
               softcap, vocab, n_tokens, block_n, block_v, filter_eps,
               accum_mode, with_sum=False, with_bitmap=False):
    refs = list(refs)
    bm_ref = refs.pop(0) if with_bitmap else None
    x_ref, gl_ref, gp_ref = refs[:3]
    refs = refs[3:]
    gs_ref = refs.pop(0) if with_sum else None
    lse_ref, e_ref, c_ref, dc_ref, acc, comp = refs
    n = pl.program_id(1)
    nn = pl.num_programs(1)
    v = pl.program_id(0)

    @pl.when(n == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        if comp is not None:
            comp[...] = jnp.zeros_like(comp)

    def _tile_and_accum():
        e = _zero_padded_rows(e_ref[...].astype(jnp.float32), n * block_n,
                              n_tokens)
        c = _zero_padded_rows(c_ref[...].astype(jnp.float32), v * block_v,
                              vocab)
        dz, live = _grad_tile(
            e, c, x_ref[...], lse_ref[...], gl_ref[...], gp_ref[...],
            softcap=softcap, vocab=vocab,
            v_start=v * block_v, n_start=n * block_n, n_tokens=n_tokens,
            g_sum=gs_ref[...] if with_sum else None)

        def _mm():   # (block_v, block_n) @ (block_n, D)
            _accum(acc, comp, jax.lax.dot_general(
                dz, e, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32), accum_mode)

        if filter_eps is not None and not with_bitmap:
            pl.when(live >= filter_eps)(_mm)
        else:
            _mm()

    if with_bitmap:
        pl.when(bm_ref[0, 0] != 0)(_tile_and_accum)
    else:
        _tile_and_accum()

    @pl.when(n == nn - 1)
    def _finalize():
        dc_ref[...] = acc[...].astype(dc_ref.dtype)


def _prep(E, C, x, lse, g_lse, g_pick, g_sum=None):
    n_tokens = E.shape[0]
    x2 = x.astype(jnp.int32).reshape(n_tokens, 1)
    gl2 = g_lse.astype(jnp.float32).reshape(n_tokens, 1)
    gp2 = g_pick.astype(jnp.float32).reshape(n_tokens, 1)
    lse2 = lse.astype(jnp.float32).reshape(n_tokens, 1)
    gs2 = (None if g_sum is None
           else g_sum.astype(jnp.float32).reshape(n_tokens, 1))
    return x2, gl2, gp2, gs2, lse2


def cce_backward_dE_pallas(E, C, x, lse, g_lse, g_pick, *, softcap=None,
                           block_n=128, block_v=256,
                           filter_eps=DEFAULT_FILTER_EPS,
                           accum="f32", g_sum=None, bitmap=None,
                           interpret=False):
    """dE (N, D) for cotangents (g_lse, g_pick[, g_sum]) of the
    (lse, pick[, sum_logits]) primitive. filter_eps=None disables gradient
    filtering (the -FullE variant); a non-None g_sum contributes a dense
    gradient that the filter statistic cannot see, so it forces
    filter_eps=None. A non-None ``bitmap`` (the forward-emitted live-block
    map, shape (cdiv(N, block_n), cdiv(V, block_v)) int32) replaces the
    recompute statistic entirely: dead blocks skip the tile recompute."""
    n_tokens, d = E.shape
    vocab = C.shape[0]
    with_sum = g_sum is not None
    if with_sum:
        filter_eps = None
        bitmap = None
    with_bitmap = bitmap is not None
    x2, gl2, gp2, gs2, lse2 = _prep(E, C, x, lse, g_lse, g_pick, g_sum)
    grid = (pl.cdiv(n_tokens, block_n), pl.cdiv(vocab, block_v))
    kernel = functools.partial(
        _de_kernel, softcap=softcap, vocab=vocab, n_tokens=n_tokens,
        block_n=block_n, block_v=block_v, filter_eps=filter_eps,
        accum_mode=accum, with_sum=with_sum, with_bitmap=with_bitmap)
    scratch = [pltpu.VMEM((block_n, d), jnp.float32)]
    if accum == "bf16_kahan":
        scratch.append(pltpu.VMEM((block_n, d), jnp.float32))
    else:
        kernel = functools.partial(_wrap_no_comp, kernel)
    tok_spec = lambda: pl.BlockSpec((block_n, 1), lambda nn, vv: (nn, 0))
    in_specs = [
        *([pl.BlockSpec((1, 1), lambda nn, vv: (nn, vv))]
          if with_bitmap else []),                           # bitmap
        tok_spec(),                                          # labels
        tok_spec(),                                          # g_lse
        tok_spec(),                                          # g_pick
        *([tok_spec()] if with_sum else []),                 # g_sum
        tok_spec(),                                          # lse
        pl.BlockSpec((block_n, d), lambda nn, vv: (nn, 0)),  # E
        pl.BlockSpec((block_v, d), lambda nn, vv: (vv, 0)),  # C
    ]
    inputs = [*([bitmap] if with_bitmap else []),
              x2, gl2, gp2, *([gs2] if with_sum else []), lse2, E, C]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n, d), lambda nn, vv: (nn, 0)),
        out_shape=sds((n_tokens, d), E.dtype, *inputs),
        scratch_shapes=scratch,
        compiler_params=_util.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*inputs)


def cce_backward_dC_pallas(E, C, x, lse, g_lse, g_pick, *, softcap=None,
                           block_n=128, block_v=256,
                           filter_eps=DEFAULT_FILTER_EPS,
                           accum="f32", g_sum=None, bitmap=None,
                           interpret=False):
    """dC (V, D) for cotangents (g_lse, g_pick[, g_sum]). filter_eps=None
    disables filtering (the -FullC variant, the paper's recommended
    pretraining setting); non-None g_sum forces it off (dense gradient).
    ``bitmap`` as in :func:`cce_backward_dE_pallas`."""
    n_tokens, d = E.shape
    vocab = C.shape[0]
    with_sum = g_sum is not None
    if with_sum:
        filter_eps = None
        bitmap = None
    with_bitmap = bitmap is not None
    x2, gl2, gp2, gs2, lse2 = _prep(E, C, x, lse, g_lse, g_pick, g_sum)
    grid = (pl.cdiv(vocab, block_v), pl.cdiv(n_tokens, block_n))
    kernel = functools.partial(
        _dc_kernel, softcap=softcap, vocab=vocab, n_tokens=n_tokens,
        block_n=block_n, block_v=block_v, filter_eps=filter_eps,
        accum_mode=accum, with_sum=with_sum, with_bitmap=with_bitmap)
    scratch = [pltpu.VMEM((block_v, d), jnp.float32)]
    if accum == "bf16_kahan":
        scratch.append(pltpu.VMEM((block_v, d), jnp.float32))
    else:
        kernel = functools.partial(_wrap_no_comp, kernel)
    tok_spec = lambda: pl.BlockSpec((block_n, 1), lambda vv, nn: (nn, 0))
    in_specs = [
        *([pl.BlockSpec((1, 1), lambda vv, nn: (nn, vv))]
          if with_bitmap else []),                           # bitmap
        tok_spec(),                                          # labels
        tok_spec(),                                          # g_lse
        tok_spec(),                                          # g_pick
        *([tok_spec()] if with_sum else []),                 # g_sum
        tok_spec(),                                          # lse
        pl.BlockSpec((block_n, d), lambda vv, nn: (nn, 0)),  # E
        pl.BlockSpec((block_v, d), lambda vv, nn: (vv, 0)),  # C
    ]
    inputs = [*([bitmap] if with_bitmap else []),
              x2, gl2, gp2, *([gs2] if with_sum else []), lse2, E, C]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_v, d), lambda vv, nn: (vv, 0)),
        out_shape=sds((vocab, d), C.dtype, *inputs),
        scratch_shapes=scratch,
        compiler_params=_util.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*inputs)


def _fused_kernel(*refs,
                  softcap, vocab, n_tokens, block_n, block_v,
                  filter_eps_e, filter_eps_c, with_sum=False,
                  with_bitmap=False, use_alias=False):
    refs = list(refs)
    bm_ref = refs.pop(0) if with_bitmap else None
    x_ref, gl_ref, gp_ref = refs[:3]
    refs = refs[3:]
    gs_ref = refs.pop(0) if with_sum else None
    if use_alias:
        lse_ref, e_ref, c_ref, dc_in_ref, de_ref, dc_ref, de_acc = refs
    else:
        lse_ref, e_ref, c_ref, de_ref, dc_ref, de_acc = refs
        dc_in_ref = None
    n = pl.program_id(0)
    nn = pl.num_programs(0)
    v = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(v == 0)
    def _init_de():
        de_acc[...] = jnp.zeros_like(de_acc)

    # dC accumulates across the (sequential) outer n axis through HBM; the
    # partial sum is carried by one of two mechanisms (see the wrapper):
    if use_alias:
        # compiled target: the output is HBM-aliased with a zeros input, and
        # the *input* window — guaranteed to be fetched every grid step —
        # carries the previous revisit's flushed partial sum. Copy-through
        # first so dead (filtered) blocks preserve it; live blocks then
        # add into the VMEM output buffer.
        dc_ref[...] = dc_in_ref[...]
    else:
        # interpret mode: output windows observably carry their previous
        # contents on revisit (aliased inputs do NOT re-read them there), so
        # accumulate in the output ref directly, seeded at first visit.
        @pl.when(n == 0)
        def _init_dc():
            dc_ref[...] = jnp.zeros_like(dc_ref)

    def _tile_and_accum():
        e = _zero_padded_rows(e_ref[...].astype(jnp.float32), n * block_n,
                              n_tokens)
        c = _zero_padded_rows(c_ref[...].astype(jnp.float32), v * block_v,
                              vocab)
        dz, live = _grad_tile(
            e, c, x_ref[...], lse_ref[...], gl_ref[...], gp_ref[...],
            softcap=softcap, vocab=vocab,
            v_start=v * block_v, n_start=n * block_n, n_tokens=n_tokens,
            g_sum=gs_ref[...] if with_sum else None)

        def _mm_e():
            de_acc[...] += jnp.dot(dz, c, preferred_element_type=jnp.float32)

        def _mm_c():  # (block_v, block_n) @ (block_n, D), into the HBM block
            dc_ref[...] += jax.lax.dot_general(
                dz, e, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        if filter_eps_e is not None and not with_bitmap:
            pl.when(live >= filter_eps_e)(_mm_e)
        else:
            _mm_e()
        if filter_eps_c is not None and not with_bitmap:
            pl.when(live >= filter_eps_c)(_mm_c)
        else:
            _mm_c()

    if with_bitmap:
        pl.when(bm_ref[0, 0] != 0)(_tile_and_accum)
    else:
        _tile_and_accum()

    @pl.when(v == nv - 1)
    def _finalize():
        de_ref[...] = de_acc[...].astype(de_ref.dtype)


# Minimum vocab-block count for the fused kernel on the compiled (TPU)
# target: the aliased dC block written at step (n, v) must be flushed to
# HBM before the input fetch for its revisit at (n+1, v) is issued. The
# write-back happens when the output index changes (step (n, v+1)) and the
# pipeline prefetches one step ahead, so a revisit distance of nv grid
# steps leaves nv - 2 steps of slack; require a margin. ops.py falls back
# to the two-pass kernels below this (interpret mode has no pipeline and
# no constraint).
FUSED_MIN_NV = 4


def cce_backward_fused_pallas(E, C, x, lse, g_lse, g_pick, *, softcap=None,
                              block_n=128, block_v=256,
                              filter_eps_e=DEFAULT_FILTER_EPS,
                              filter_eps_c=DEFAULT_FILTER_EPS,
                              g_sum=None, bitmap=None, interpret=False):
    """Single-pass fused backward: ``(dE, dC_f32)`` from ONE logit-tile
    recompute per (n, v) block (DESIGN.md §7).

    Grid (n, v), both axes sequential ("arbitrary"): dE accumulates over the
    innermost v axis in VMEM scratch exactly like the two-pass dE kernel;
    dC accumulates across the outer n axis through its HBM-backed block —
    via an ``input_output_aliases``'d zeros input on the compiled target
    (input windows are re-fetched every grid step by contract; see
    ``FUSED_MIN_NV`` for the flush-distance guard) and via the readable
    output window in interpret mode (where aliased inputs observably do
    NOT carry the accumulation). dC is returned in f32 — the same addends
    in the same order as the two-pass f32 VMEM accumulation, so casting it
    to C.dtype is bit-identical to the two-pass result. Kahan / bf16
    accumulation modes are two_pass-only (the dispatch in ops.py falls
    back); a non-None ``g_sum`` forces filtering off, as in the two-pass
    kernels. With ``bitmap`` (requires both sides filtered) dead blocks
    skip the recompute; with the recompute statistic, each side's matmul is
    gated on its own ``filter_eps_*``.

    Note the trade: fused halves the recompute FLOPs but streams the f32
    dC array through HBM once per n-block (read+write ≈ 8·nn·V·D bytes vs
    one write from VMEM in two_pass) — on HBM-bandwidth-bound geometries
    two_pass can win wall-clock; ``benchmarks/tableA2`` reports both
    FLOPs and the traffic estimate per combination.
    """
    n_tokens, d = E.shape
    vocab = C.shape[0]
    with_sum = g_sum is not None
    if with_sum:
        filter_eps_e = filter_eps_c = None
        bitmap = None
    with_bitmap = bitmap is not None
    if with_bitmap:
        # The bitmap gates the shared tile recompute, so it can only stand
        # in for the statistic when BOTH sides filter (ops.py guarantees).
        assert filter_eps_e is not None and filter_eps_c is not None
    use_alias = not interpret
    x2, gl2, gp2, gs2, lse2 = _prep(E, C, x, lse, g_lse, g_pick, g_sum)
    grid = (pl.cdiv(n_tokens, block_n), pl.cdiv(vocab, block_v))
    kernel = functools.partial(
        _fused_kernel, softcap=softcap, vocab=vocab, n_tokens=n_tokens,
        block_n=block_n, block_v=block_v, filter_eps_e=filter_eps_e,
        filter_eps_c=filter_eps_c, with_sum=with_sum,
        with_bitmap=with_bitmap, use_alias=use_alias)
    tok_spec = lambda: pl.BlockSpec((block_n, 1), lambda nn_, vv: (nn_, 0))
    dc_spec = lambda: pl.BlockSpec((block_v, d), lambda nn_, vv: (vv, 0))
    in_specs = [
        *([pl.BlockSpec((1, 1), lambda nn_, vv: (nn_, vv))]
          if with_bitmap else []),                            # bitmap
        tok_spec(),                                           # labels
        tok_spec(),                                           # g_lse
        tok_spec(),                                           # g_pick
        *([tok_spec()] if with_sum else []),                  # g_sum
        tok_spec(),                                           # lse
        pl.BlockSpec((block_n, d), lambda nn_, vv: (nn_, 0)),  # E
        pl.BlockSpec((block_v, d), lambda nn_, vv: (vv, 0)),   # C
        *([dc_spec()] if use_alias else []),                   # dC seed
    ]
    inputs = [*([bitmap] if with_bitmap else []),
              x2, gl2, gp2, *([gs2] if with_sum else []), lse2, E, C]
    if use_alias:
        inputs.append(jnp.zeros((vocab, d), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_n, d), lambda nn_, vv: (nn_, 0)),  # dE
            dc_spec(),                                             # dC
        ],
        out_shape=[sds((n_tokens, d), E.dtype, *inputs),
                   sds((vocab, d), jnp.float32, *inputs)],
        scratch_shapes=[pltpu.VMEM((block_n, d), jnp.float32)],
        input_output_aliases={len(inputs) - 1: 1} if use_alias else {},
        compiler_params=_util.compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*inputs)


def _wrap_no_comp(kernel, *refs):
    """Adapter: insert comp=None for non-Kahan accumulation modes."""
    *io_refs, acc = refs
    return kernel(*io_refs, acc, None)
