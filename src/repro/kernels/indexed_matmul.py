"""Standalone memory-efficient indexed matmul (paper Algorithm 1).

Computes ``o_i = C[x_i] . E_i`` in O(N) global memory without materializing
the gathered classifier rows ``C_x`` (O(N*D)) or the logits (O(N*V)).

TPU adaptation (DESIGN.md §2): the paper's Triton kernel issues per-token
global-memory gathers of classifier columns. The TPU-native equivalent is
**scalar prefetch**: the label vector is prefetched into SMEM and used inside
the *block index map* of ``C``, so the Pallas pipeline DMAs exactly the one
classifier row each token needs from HBM into VMEM — a gather expressed as
data-dependent block scheduling rather than in-kernel pointer arithmetic.

This standalone op is used for testing/parity and for embedding-style
lookups; the production CCE loss uses the fused forward (cce_fwd.py), where
the label logit is a free by-product of the LSE tile sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import sds


def _idx_mm_kernel(x_smem, e_ref, c_ref, o_ref, *, softcap):
    del x_smem  # only used by the index maps
    e = e_ref[...].astype(jnp.float32)  # (1, D)
    c = c_ref[...].astype(jnp.float32)  # (1, D) — the row C[x_i]
    o = jnp.sum(e * c)
    if softcap is not None:
        o = softcap * jnp.tanh(o / softcap)
    o_ref[0, 0] = o


def indexed_matmul_pallas(E: jax.Array, C: jax.Array, x: jax.Array, *,
                          softcap: float | None = None,
                          interpret: bool = False) -> jax.Array:
    """o_i = softcap(C[x_i] . E_i), shape (N,), f32."""
    n_tokens, d = E.shape
    assert C.shape[1] == d

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tokens,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, x_s: (i, 0)),        # E row i
            pl.BlockSpec((1, d), lambda i, x_s: (x_s[i], 0)),   # C row x[i]
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, x_s: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_idx_mm_kernel, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=sds((n_tokens, 1), jnp.float32, x, E, C),
        interpret=interpret,
    )(x.astype(jnp.int32), E, C)
    return out[:, 0]
