"""Fused projection->sample Pallas kernel: logit-free decode (DESIGN.md §10).

The serve engine's decode step is the inference-time dual of the training
problem this repo exists for: ``serve_step`` materializes the full
``(B, V)`` logit matrix only so the sampler can immediately reduce it to
one token id per row. This kernel streams ``C^T h`` blockwise over the
vocabulary — reusing the online-LSE scratch discipline of
:mod:`repro.kernels.cce_fwd` — and emits only ``(token, logprob)`` per
row. The ``(B, V)`` logits never exist outside one ``(block_b, block_v)``
VMEM tile.

Per-row sampling policy (all vector params, mixed freely in one batch):

  * **greedy** (``temperature == 0``) — a running argmax over the raw
    (softcapped) logits carried in VMEM scratch; first-occurrence tie
    semantics identical to ``jnp.argmax``. ``logprob`` is the winner's
    raw logit minus the full online LSE.
  * **temperature** — exact streaming Gumbel-max: per-(row, column)
    Gumbel noise derived from the row's PRNG key by a counter-based hash
    (below), running max of ``logit/τ + g``. Token-exact between the
    Pallas kernel and the pure-JAX twin.
  * **top-k / top-p** — the two-phase LSE-then-threshold scheme: a stats
    sweep (online LSE + max/min + greedy argmax), a histogram sweep that
    converts the suffix count/mass over ``n_buckets`` equal bins of the
    scaled-logit range into per-row keep thresholds, then the filtered
    Gumbel-max sweep with a kept-set LSE for the renormalized logprob.
    The kept set is a conservative SUPERSET of the exact top-k/top-p
    filter — see DESIGN.md §10 for the exactness contract.

Noise: Pallas-TPU's ``pltpu.prng_*`` primitives have no interpret-mode
lowering on CPU, so the Gumbel noise comes from a stateless counter-based
hash (two multiply-xorshift finalizer rounds keyed by the row's PRNG key,
counter = global column index) implemented in plain ``jnp`` uint32 ops.
The same function runs inside the kernel, under interpret mode, and in
the reference twin — the three paths are noise-identical by construction,
which is what makes fused-vs-twin token equality testable at all.

CPU execution dispatches to :func:`decode_sample_ref`, a blockwise
``lax.fori_loop`` twin with identical per-tile math (the interpret-mode
kernel is kept for parity tests; the twin is the fast CPU path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _util
from repro.kernels._util import VMEM_BUDGET as _VMEM_BUDGET
from repro.kernels._util import sds
from repro.kernels.ops import _is_cpu

_NEG = float("-inf")
#: Tokens with renormalized probability below this floor may be dropped
#: from a top-k keep set that cannot reach them (DESIGN.md §10 contract).
PROB_FLOOR = 1e-9
_LOG_FLOOR = float(jnp.log(PROB_FLOOR))
#: Default number of histogram bins for the threshold sweep.
DEFAULT_BUCKETS = 256


# ---------------------------------------------------------------------------
# Counter-based noise + shared per-tile math (kernel AND twin run these).
# ---------------------------------------------------------------------------

def _fmix(x):
    """murmur3 finalizer: full-avalanche mix of a uint32."""
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> jnp.uint32(13))) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> jnp.uint32(16))


def _noise_bits(col, k0, k1):
    """Stateless hash: (global column, row key) -> uint32.

    Two full murmur3-fmix rounds, one per key word, so rows whose PRNG
    keys differ in a single low bit (e.g. ``PRNGKey(i)`` for consecutive
    ``i``) still get independent streams. Plain uint32 jnp ops only, so
    the exact same bits come out of the compiled TPU kernel, the
    interpreter, and the reference twin."""
    x = col.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x = _fmix(x ^ k0)
    return _fmix(x ^ k1)


def _gumbel(col, k0, k1):
    """Per-(row, column) standard Gumbel noise from the hash bits."""
    bits = _noise_bits(col, k0, k1)
    # top 24 bits -> u in (0, 1): exact in f32, never 0 or 1
    u = ((bits >> jnp.uint32(8)).astype(jnp.int32).astype(jnp.float32)
         * jnp.float32(2.0 ** -24) + jnp.float32(2.0 ** -25))
    return -jnp.log(-jnp.log(u))


def _tile_scores(h, c, vb, *, block_v, vocab, softcap, tau_safe):
    """One (rows, block_v) tile of raw + scaled logits, never in HBM.

    Returns (a, s, col, valid): raw softcapped logits (padded columns
    -inf), temperature-scaled logits, global column ids, validity mask.
    ``tau_safe`` is ``where(temperature > 0, temperature, 1)`` so greedy
    rows score on the raw-logit scale (their LSE is the raw LSE);
    ``tau_safe=None`` (the static all-greedy fast path) skips the scaled
    copy entirely — ``s`` aliases ``a``."""
    a = jax.lax.dot_general(h, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap is not None:
        a = softcap * jnp.tanh(a / softcap)
    col = vb * block_v + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    valid = col < vocab
    a = jnp.where(valid, a, _NEG)
    s = a if tau_safe is None else jnp.where(valid, a / tau_safe, _NEG)
    return a, s, col, valid


def _block_argmax(x, col):
    """(rows,) max + the FIRST column attaining it (jnp.argmax ties)."""
    bm = jnp.max(x, axis=1, keepdims=True)
    bi = jnp.min(jnp.where(x == bm, col, jnp.int32(2 ** 30)),
                 axis=1, keepdims=True)
    return bm, bi


def _online_lse(m_old, s_old, tile):
    """One streaming-LSE update step (cce_fwd's recurrence)."""
    bmax = jnp.max(tile, axis=1, keepdims=True)
    m_new = jnp.maximum(m_old, bmax)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    s_new = (s_old * jnp.exp(m_old - m_safe)
             + jnp.sum(jnp.exp(tile - m_safe), axis=1, keepdims=True))
    return m_new, s_new


def _hist_update(hist_c, hist_m, s, valid, fl, wd, lse, *, n_buckets):
    """Accumulate this tile into the per-row count/mass histograms.

    Bucket j spans scaled logits ``[fl + j·wd/NH, fl + (j+1)·wd/NH)``;
    tokens below ``fl`` (prob < PROB_FLOOR, see contract) are dropped."""
    rel = (s - fl) / wd * n_buckets
    q = jnp.floor(rel).astype(jnp.int32)
    keep = valid & (q >= 0)
    q = jnp.clip(q, 0, n_buckets - 1)
    oh = ((q[:, :, None]
           == jax.lax.broadcasted_iota(jnp.int32,
                                       q.shape + (n_buckets,), 2))
          & keep[:, :, None]).astype(jnp.float32)
    w = jnp.where(keep, jnp.exp(s - lse), 0.0)
    return (hist_c + jnp.sum(oh, axis=1),
            hist_m + jnp.sum(oh * w[:, :, None], axis=1))


def _thresholds(hist_c, hist_m, fl, wd, kf, pf, *, n_buckets):
    """Histogram -> per-row keep threshold θ (−inf when no filter).

    ``suffix[j] = count/mass of tokens with s >= bucket-j lower edge``
    via one matmul with a constant lower-triangular matrix; θ_k is the
    LOWEST bucket edge whose suffix count still reaches k (a superset of
    exact top-k), θ_p likewise for mass p. Disabled filters (k <= 0,
    p >= 1) contribute −inf; θ = max of the enabled ones."""
    tri = (jax.lax.broadcasted_iota(jnp.int32, (n_buckets, n_buckets), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (n_buckets, n_buckets),
                                       1)).astype(jnp.float32)
    sc = jax.lax.dot_general(hist_c, tri, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    sm = jax.lax.dot_general(hist_m, tri, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    step = wd / n_buckets
    jk = jnp.sum((sc >= kf).astype(jnp.float32), axis=1,
                 keepdims=True) - 1.0
    jp = jnp.sum((sm >= pf).astype(jnp.float32), axis=1,
                 keepdims=True) - 1.0
    th_k = fl + jnp.clip(jk, 0.0, n_buckets - 1) * step
    th_p = fl + jnp.clip(jp, 0.0, n_buckets - 1) * step
    th_k = jnp.where(kf > 0.5, th_k, _NEG)
    th_p = jnp.where(pf < 1.0, th_p, _NEG)
    return jnp.maximum(th_k, th_p)


def _gumbel_update(pm, pi, pv, s_kept, col, k0, k1):
    """One streaming Gumbel-max step: perturb the kept scaled logits,
    keep the best (perturbed max, token id, unperturbed scaled logit)."""
    pert = jnp.where(s_kept > _NEG, s_kept + _gumbel(col, k0, k1), _NEG)
    bm, bi = _block_argmax(pert, col)
    bv_ = jnp.sum(jnp.where((pert == bm) & (col == bi), s_kept, 0.0),
                  axis=1, keepdims=True)
    upd = bm > pm
    return (jnp.maximum(pm, bm), jnp.where(upd, bi, pi),
            jnp.where(upd, bv_, pv))


# ---------------------------------------------------------------------------
# VMEM accounting (the choose_blocks discipline, decode-shaped).
# ---------------------------------------------------------------------------

def decode_vmem_working_set(block_b: int, block_v: int, d: int,
                            itemsize: int, *, with_filter: bool = True,
                            n_buckets: int = DEFAULT_BUCKETS) -> int:
    """Estimated VMEM bytes one grid step of the decode kernel keeps live:
    double-buffered h/C tiles, the f32 logit tile, ~12 per-row scratch
    columns, and (filtered only) the two histograms, the rank-3 one-hot
    temporary of the histogram sweep, and the constant suffix-sum
    matrix."""
    ws = (2 * (block_b + block_v) * d * itemsize
          + 2 * block_b * block_v * 4          # raw + scaled logit tiles
          + 12 * block_b * 4)
    if with_filter:
        ws += (2 * block_b * n_buckets * 4
               + block_b * block_v * n_buckets * 4
               + n_buckets * n_buckets * 4)
    return ws


def choose_decode_blocks(batch: int, vocab: int, d: int, itemsize: int,
                         *, with_filter: bool = True,
                         n_buckets: int = DEFAULT_BUCKETS
                         ) -> tuple[int, int]:
    """Pick (block_b, block_v) multiples of the (8, 128) TPU tile with
    :func:`decode_vmem_working_set` under the shared VMEM budget.
    ``block_b`` stays small (decode batches are narrow); ``block_v``
    starts wide and halves until the working set fits."""
    bb = max(8, min(32, _round_up(batch, 8)))
    bv = 512
    while bv > 128 and decode_vmem_working_set(
            bb, bv, d, itemsize, with_filter=with_filter,
            n_buckets=n_buckets) > _VMEM_BUDGET:
        bv //= 2
    while bb > 8 and decode_vmem_working_set(
            bb, bv, d, itemsize, with_filter=with_filter,
            n_buckets=n_buckets) > _VMEM_BUDGET:
        bb //= 2
    return bb, max(128, min(bv, _round_up(vocab, 128)))


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Pallas kernel.
# ---------------------------------------------------------------------------

def _decode_kernel(h_ref, k_ref, t_ref, tk_ref, tp_ref, c_ref,
                   tok_ref, lp_ref, *scr,
                   softcap, vocab, block_v, nv, with_filter, with_sample,
                   n_buckets):
    (m_acc, s_acc, mn_acc, l_acc, gm_acc, th_acc, fl_acc, wd_acc,
     pm_acc, pv_acc, gi_acc, pi_acc) = scr[:12]
    hc_acc, hm_acc = (scr[12], scr[13]) if with_filter else (None, None)

    v = pl.program_id(1)
    vb = jax.lax.rem(v, nv)
    phase = v // nv

    tau = t_ref[...]                                     # (block_b, 1)
    tau_safe = jnp.where(tau > 0.0, tau, 1.0) if with_sample else None
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    a, s, col, valid = _tile_scores(
        h, c, vb, block_v=block_v, vocab=vocab, softcap=softcap,
        tau_safe=tau_safe)
    k0 = k_ref[:, 0:1]
    k1 = k_ref[:, 1:2]

    if not with_filter:
        # Single sweep: online LSE + greedy argmax (+ Gumbel-max when any
        # row samples; an all-greedy batch skips the noise hash and the
        # perturbed-max recurrence entirely — with_sample is static, like
        # with_filter, chosen host-side from the admitted requests).
        @pl.when(vb == 0)
        def _init():
            m_acc[...] = jnp.full_like(m_acc, _NEG)
            s_acc[...] = jnp.zeros_like(s_acc)
            gm_acc[...] = jnp.full_like(gm_acc, _NEG)
            gi_acc[...] = jnp.zeros_like(gi_acc)
            if with_sample:
                pm_acc[...] = jnp.full_like(pm_acc, _NEG)
                pi_acc[...] = jnp.zeros_like(pi_acc)
                pv_acc[...] = jnp.zeros_like(pv_acc)

        m_acc[...], s_acc[...] = _online_lse(m_acc[...], s_acc[...], s)
        bm, bi = _block_argmax(a, col)
        upd = bm > gm_acc[...]
        gi_acc[...] = jnp.where(upd, bi, gi_acc[...])
        gm_acc[...] = jnp.maximum(gm_acc[...], bm)
        if with_sample:
            pm_acc[...], pi_acc[...], pv_acc[...] = _gumbel_update(
                pm_acc[...], pi_acc[...], pv_acc[...], s, col, k0, k1)

        @pl.when(vb == nv - 1)
        def _done():
            lse = m_acc[...] + jnp.log(s_acc[...])
            if with_sample:
                g = tau <= 0.0
                tok_ref[...] = jnp.where(g, gi_acc[...], pi_acc[...])
                lp_ref[...] = jnp.where(g, gm_acc[...] - lse,
                                        pv_acc[...] - lse)
            else:
                tok_ref[...] = gi_acc[...]
                lp_ref[...] = gm_acc[...] - lse
        return

    # -- phase 0: stats sweep (full LSE, scaled max/min, greedy argmax) --
    @pl.when(phase == 0)
    def _stats():
        @pl.when(vb == 0)
        def _init():
            m_acc[...] = jnp.full_like(m_acc, _NEG)
            s_acc[...] = jnp.zeros_like(s_acc)
            mn_acc[...] = jnp.full_like(mn_acc, jnp.inf)
            gm_acc[...] = jnp.full_like(gm_acc, _NEG)
            gi_acc[...] = jnp.zeros_like(gi_acc)

        m_acc[...], s_acc[...] = _online_lse(m_acc[...], s_acc[...], s)
        mn_acc[...] = jnp.minimum(
            mn_acc[...],
            jnp.min(jnp.where(valid, s, jnp.inf), axis=1, keepdims=True))
        bm, bi = _block_argmax(a, col)
        upd = bm > gm_acc[...]
        gi_acc[...] = jnp.where(upd, bi, gi_acc[...])
        gm_acc[...] = jnp.maximum(gm_acc[...], bm)

        @pl.when(vb == nv - 1)
        def _fin():
            lse = m_acc[...] + jnp.log(s_acc[...])
            l_acc[...] = lse
            fl = jnp.maximum(mn_acc[...], lse + _LOG_FLOOR)
            fl_acc[...] = fl
            wd_acc[...] = jnp.maximum(m_acc[...] - fl, 1e-6)

    # -- phase 1: histogram sweep -> per-row keep threshold --------------
    @pl.when(phase == 1)
    def _hist():
        @pl.when(vb == 0)
        def _init():
            hc_acc[...] = jnp.zeros_like(hc_acc)
            hm_acc[...] = jnp.zeros_like(hm_acc)

        hc_acc[...], hm_acc[...] = _hist_update(
            hc_acc[...], hm_acc[...], s, valid, fl_acc[...], wd_acc[...],
            l_acc[...], n_buckets=n_buckets)

        @pl.when(vb == nv - 1)
        def _fin():
            th_acc[...] = _thresholds(
                hc_acc[...], hm_acc[...], fl_acc[...], wd_acc[...],
                tk_ref[...], tp_ref[...], n_buckets=n_buckets)

    # -- phase 2: filtered Gumbel-max + kept-set LSE ---------------------
    @pl.when(phase == 2)
    def _sample():
        @pl.when(vb == 0)
        def _init():
            # m/s are free again (full LSE saved in l_acc): reuse for the
            # kept-set LSE of the renormalized filtered distribution
            m_acc[...] = jnp.full_like(m_acc, _NEG)
            s_acc[...] = jnp.zeros_like(s_acc)
            pm_acc[...] = jnp.full_like(pm_acc, _NEG)
            pi_acc[...] = jnp.zeros_like(pi_acc)
            pv_acc[...] = jnp.zeros_like(pv_acc)

        s_kept = jnp.where(s >= th_acc[...], s, _NEG)
        m_acc[...], s_acc[...] = _online_lse(m_acc[...], s_acc[...],
                                             s_kept)
        pm_acc[...], pi_acc[...], pv_acc[...] = _gumbel_update(
            pm_acc[...], pi_acc[...], pv_acc[...], s_kept, col, k0, k1)

        @pl.when(vb == nv - 1)
        def _done():
            kept_lse = m_acc[...] + jnp.log(s_acc[...])
            g = tau <= 0.0
            tok_ref[...] = jnp.where(g, gi_acc[...], pi_acc[...])
            lp_ref[...] = jnp.where(g, gm_acc[...] - l_acc[...],
                                    pv_acc[...] - kept_lse)


def decode_sample_pallas(h, C, keys, temperature, top_k, top_p, *,
                         vocab: int, softcap: float | None = None,
                         with_filter: bool = True,
                         with_sample: bool = True,
                         block_b: int = 8, block_v: int = 512,
                         n_buckets: int = DEFAULT_BUCKETS,
                         interpret: bool = False):
    """Fused projection->sample: (token (B,), logprob (B,)) per row.

    h: (B, D); C: (V_pad, D) classifier rows (``vocab`` <= V_pad real
    columns); keys: (B, 2) uint32 per-row PRNG keys; temperature/top_p:
    (B,) f32; top_k: (B,) int. ``with_filter`` is static: the False
    variant is a single vocab sweep (greedy + pure-temperature rows), the
    True variant runs the stats/histogram/sample three-sweep scheme.
    ``with_sample=False`` (requires an all-greedy batch: every
    ``temperature == 0``) additionally drops the noise hash and the
    Gumbel-max recurrence — the sweep is a pure streaming argmax + LSE.
    """
    b, d = h.shape
    vpad, d2 = C.shape
    assert d == d2, (h.shape, C.shape)
    if not with_sample:
        with_filter = False      # filters only exist for sampled rows
    nb, nv = pl.cdiv(b, block_b), pl.cdiv(vpad, block_v)
    phases = 3 if with_filter else 1
    grid = (nb, phases * nv)

    keys = jnp.asarray(keys, jnp.uint32).reshape(b, 2)
    t2 = jnp.asarray(temperature, jnp.float32).reshape(b, 1)
    tk2 = jnp.asarray(top_k, jnp.float32).reshape(b, 1)
    tp2 = jnp.asarray(top_p, jnp.float32).reshape(b, 1)

    kernel = functools.partial(
        _decode_kernel, softcap=softcap, vocab=vocab, block_v=block_v,
        nv=nv, with_filter=with_filter, with_sample=with_sample,
        n_buckets=n_buckets)

    row_spec = lambda w: pl.BlockSpec((block_b, w), lambda nb_, v: (nb_, 0))
    scratch = ([pltpu.VMEM((block_b, 1), jnp.float32)
                for _ in range(10)]
               + [pltpu.VMEM((block_b, 1), jnp.int32) for _ in range(2)])
    if with_filter:
        scratch += [pltpu.VMEM((block_b, n_buckets), jnp.float32)
                    for _ in range(2)]
    tok, lp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            row_spec(d),                                  # h
            row_spec(2),                                  # keys
            row_spec(1), row_spec(1), row_spec(1),        # tau / k / p
            pl.BlockSpec((block_v, d),
                         lambda nb_, v: (jax.lax.rem(v, nv), 0)),   # C
        ],
        out_specs=[row_spec(1), row_spec(1)],
        out_shape=[sds((b, 1), jnp.int32, h, C),
                   sds((b, 1), jnp.float32, h, C)],
        scratch_shapes=scratch,
        compiler_params=_util.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(h, keys, t2, tk2, tp2, C)
    return tok[:, 0], lp[:, 0]


# ---------------------------------------------------------------------------
# Pure-JAX reference twin (the CPU execution path).
# ---------------------------------------------------------------------------

def decode_sample_ref(h, C, keys, temperature, top_k, top_p, *,
                      vocab: int, softcap: float | None = None,
                      with_filter: bool = True, with_sample: bool = True,
                      block_v: int = 512, block_b: int = 8,
                      n_buckets: int = DEFAULT_BUCKETS,
                      labels=None, exclude=None):
    """Blockwise twin of the kernel: identical per-tile math and noise,
    so tokens are bit-identical to the Pallas kernel. Never materializes
    ``(B, V)``: rows go through ``lax.map`` in ``block_b`` chunks (rows
    are independent, so chunking is numerically free) and the vocab is a
    ``fori_loop`` over ``block_v`` tiles — the widest live arrays are one
    ``(block_b, block_v)`` tile and the ``(block_b, block_v, n_buckets)``
    histogram temporary, mirroring the kernel's VMEM footprint.

    Speculative-verification extras (DESIGN.md §12), both optional:

    * ``labels`` (B,) int32 — adds a THIRD output ``label_lp``: the
      logprob the row's own sampling distribution assigns to
      ``labels[b]`` (raw softmax for greedy rows, renormalized kept-set
      distribution for filtered rows; −inf when the label falls outside
      the kept set). Accumulated inside the stats sweep — the label's
      logit is picked out of the tile it lives in, so the extra cost is
      one masked reduction per tile, never a ``(B, V)`` gather.
    * ``exclude`` (B,) int32, −1 = none — masks that token out of the
      *sampled* Gumbel-max pick only. The kept-set LSE and the greedy
      argmax are untouched, so a Gumbel draw with ``exclude=d`` samples
      exactly the residual distribution ``p`` restricted to the
      complement of ``d`` — the speculative rejection correction
      ``max(p − q, 0)`` for a deterministic (point-mass) drafter. The
      reported ``lp`` for the picked token stays ``log p`` under the
      UNexcluded distribution (the quantity the output logprob contract
      promises).
    """
    b, d = h.shape
    if not with_sample:
        with_filter = False      # filters only exist for sampled rows
    with_labels = labels is not None
    with_exclude = exclude is not None
    vpad = C.shape[0]
    pad = (-vpad) % block_v
    if pad:
        C = jnp.pad(C, ((0, pad), (0, 0)))
    nv = (vpad + pad) // block_v
    h = h.astype(jnp.float32)
    keys = jnp.asarray(keys, jnp.uint32).reshape(b, 2)
    tau_v = jnp.asarray(temperature, jnp.float32).reshape(b)
    kf_v = jnp.asarray(top_k, jnp.float32).reshape(b)
    pf_v = jnp.asarray(top_p, jnp.float32).reshape(b)
    lab_v = (jnp.clip(jnp.asarray(labels, jnp.int32).reshape(b),
                      0, vocab - 1)
             if with_labels else jnp.zeros((b,), jnp.int32))
    exc_v = (jnp.asarray(exclude, jnp.int32).reshape(b)
             if with_exclude else jnp.full((b,), -1, jnp.int32))

    def one_chunk(args):
        hc, kc, tau, kf, pf, lab, exc = args
        bb = hc.shape[0]
        k0, k1 = kc[:, 0:1], kc[:, 1:2]
        tau = tau[:, None]
        kf = kf[:, None]
        pf = pf[:, None]
        lab = lab[:, None]
        exc = exc[:, None]
        tau_safe = jnp.where(tau > 0.0, tau, 1.0) if with_sample else None

        def tile(vb):
            c = jax.lax.dynamic_slice_in_dim(C, vb * block_v, block_v, 0)
            return _tile_scores(hc, c.astype(jnp.float32), vb,
                                block_v=block_v, vocab=vocab,
                                softcap=softcap, tau_safe=tau_safe)

        col1 = jnp.zeros((bb, 1), jnp.float32)
        coli = jnp.zeros((bb, 1), jnp.int32)

        def sweep(body, init):
            # single-tile sweeps run straight-line: a trip-count-1
            # fori_loop is a fusion barrier on XLA:CPU, and the unrolled
            # form is op-for-op identical
            if nv == 1:
                return body(0, init)
            return jax.lax.fori_loop(0, nv, body, init)

        def stats_body(vb, carry):
            if with_labels:
                m, se, mn, gm, gi, al = carry
            else:
                m, se, mn, gm, gi = carry
            a, s, col, valid = tile(vb)
            m, se = _online_lse(m, se, s)
            mn = jnp.minimum(mn, jnp.min(jnp.where(valid, s, jnp.inf),
                                         axis=1, keepdims=True))
            bm, bi = _block_argmax(a, col)
            upd = bm > gm
            gm, gi = jnp.maximum(gm, bm), jnp.where(upd, bi, gi)
            if with_labels:
                # the label id lives in exactly one (valid) tile column,
                # so a masked sum per tile accumulates its raw logit
                al = al + jnp.sum(jnp.where(col == lab, a, 0.0),
                                  axis=1, keepdims=True)
                return m, se, mn, gm, gi, al
            return m, se, mn, gm, gi

        stats_init = (col1 + _NEG, col1, col1 + jnp.inf, col1 + _NEG,
                      coli)
        if with_labels:
            m, se, mn, gm, gi, al = sweep(stats_body,
                                          stats_init + (col1,))
        else:
            m, se, mn, gm, gi = sweep(stats_body, stats_init)
            al = None
        lse = m + jnp.log(se)

        if with_filter:
            fl = jnp.maximum(mn, lse + _LOG_FLOOR)
            wd = jnp.maximum(m - fl, 1e-6)

            def hist_body(vb, carry):
                hc_, hm_ = carry
                _, s, _, valid = tile(vb)
                return _hist_update(hc_, hm_, s, valid, fl, wd, lse,
                                    n_buckets=n_buckets)

            hcnt, hmass = sweep(
                hist_body,
                (jnp.zeros((bb, n_buckets), jnp.float32),
                 jnp.zeros((bb, n_buckets), jnp.float32)))
            th = _thresholds(hcnt, hmass, fl, wd, kf, pf,
                             n_buckets=n_buckets)
        else:
            th = col1 + _NEG

        if not with_sample:
            # all-greedy batch: no noise hash, no Gumbel recurrence — the
            # stats sweep above already holds the argmax and the LSE
            if with_labels:
                return gi[:, 0], (gm - lse)[:, 0], (al - lse)[:, 0]
            return gi[:, 0], (gm - lse)[:, 0]

        def sample_body(vb, carry):
            km, ks, pm, pi, pv = carry
            _, s, col, _ = tile(vb)
            s_kept = jnp.where(s >= th, s, _NEG)
            km, ks = _online_lse(km, ks, s_kept)
            # exclusion masks the Gumbel pick only: the kept-set LSE
            # still covers the full kept set, so the pick is the exact
            # residual draw while lp keeps the unexcluded convention
            s_pick = (jnp.where(col == exc, _NEG, s_kept)
                      if with_exclude else s_kept)
            pm, pi, pv = _gumbel_update(pm, pi, pv, s_pick, col, k0, k1)
            return km, ks, pm, pi, pv

        km, ks, pm, pi, pv = sweep(
            sample_body,
            (col1 + _NEG, col1, col1 + _NEG, coli, col1))
        kept_lse = km + jnp.log(ks)

        g = tau <= 0.0
        tok = jnp.where(g, gi, pi)
        lp = jnp.where(g, gm - lse, pv - kept_lse)
        if with_labels:
            # filtered rows: the label must survive the keep threshold;
            # unfiltered rows have th = -inf and kept_lse == lse, so the
            # same expression degenerates to s_label - lse
            s_label = al / tau_safe
            samp_lp = jnp.where(s_label >= th, s_label - kept_lse, _NEG)
            label_lp = jnp.where(g, al - lse, samp_lp)
            return tok[:, 0], lp[:, 0], label_lp[:, 0]
        return tok[:, 0], lp[:, 0]

    rpad = (-b) % block_b
    if rpad:
        h = jnp.pad(h, ((0, rpad), (0, 0)))
        keys = jnp.pad(keys, ((0, rpad), (0, 0)))
        tau_v = jnp.pad(tau_v, (0, rpad))
        kf_v = jnp.pad(kf_v, (0, rpad))
        pf_v = jnp.pad(pf_v, (0, rpad), constant_values=1.0)
        lab_v = jnp.pad(lab_v, (0, rpad))
        exc_v = jnp.pad(exc_v, (0, rpad), constant_values=-1)
    nb = (b + rpad) // block_b
    if nb == 1:
        # one chunk: skip the lax.map scan wrapper (another fusion
        # barrier) — identical math, straight-line
        out = one_chunk((h, keys, tau_v, kf_v, pf_v, lab_v, exc_v))
        return tuple(o[:b] for o in out)
    chunked = (h.reshape(nb, block_b, d),
               keys.reshape(nb, block_b, 2),
               tau_v.reshape(nb, block_b),
               kf_v.reshape(nb, block_b),
               pf_v.reshape(nb, block_b),
               lab_v.reshape(nb, block_b),
               exc_v.reshape(nb, block_b))
    out = jax.lax.map(one_chunk, chunked)
    return tuple(o.reshape(-1)[:b] for o in out)


# ---------------------------------------------------------------------------
# Dispatcher.
# ---------------------------------------------------------------------------

def decode_sample(h, C, keys, temperature, top_k, top_p, *, vocab: int,
                  softcap: float | None = None, with_filter: bool = True,
                  with_sample: bool = True,
                  block_b: int | None = None, block_v: int | None = None,
                  n_buckets: int = DEFAULT_BUCKETS,
                  use_kernel: bool | None = None,
                  interpret: bool | None = None,
                  labels=None, exclude=None):
    """Fused logit-free decode sampling; auto-dispatches TPU kernel vs
    pure-JAX twin (twin on CPU — the pltpu PRNG-free noise makes them
    token-identical, so the choice is pure performance).

    ``labels``/``exclude`` (speculative verification, DESIGN.md §12)
    route to the reference twin: the sweep math is identical, the label
    logprob rides the stats sweep, and the exclusion masks only the
    Gumbel pick — see :func:`decode_sample_ref`. Extending the Pallas
    kernel with the same two scratch columns is a straightforward
    follow-up; the serve engine only needs the twin (its CPU execution
    path) today. With ``labels`` the return is a 3-tuple
    ``(token, logprob, label_lp)``; without, the 2-tuple is unchanged."""
    b, d = h.shape
    if not with_sample:
        with_filter = False
    if use_kernel is None:
        use_kernel = not _is_cpu()
    if labels is not None or exclude is not None:
        use_kernel = False
    if block_b is None or block_v is None:
        if use_kernel:
            cb, cv = choose_decode_blocks(b, C.shape[0], d,
                                          h.dtype.itemsize,
                                          with_filter=with_filter,
                                          n_buckets=n_buckets)
        else:
            # the twin has no VMEM ceiling — tiny TPU tiles only
            # serialize XLA:CPU into a slow fori_loop. Unfiltered, one
            # full-vocab tile makes the sweep a single fused
            # matmul+reduce (the live tile is (block_b, V_pad), still
            # never (B, V)); filtered, the (block_b, block_v, n_buckets)
            # histogram one-hot bounds the tile at 2048 columns (~16 MB
            # of f32 temp at the default 256 buckets).
            cb = min(8, b)
            cv = C.shape[0] if not with_filter \
                else min(C.shape[0], 2048)
        block_b = block_b or cb
        block_v = block_v or cv
    if use_kernel:
        return decode_sample_pallas(
            h, C, keys, temperature, top_k, top_p, vocab=vocab,
            softcap=softcap, with_filter=with_filter,
            with_sample=with_sample, block_b=block_b,
            block_v=block_v, n_buckets=n_buckets,
            interpret=_is_cpu() if interpret is None else interpret)
    return decode_sample_ref(
        h, C, keys, temperature, top_k, top_p, vocab=vocab,
        softcap=softcap, with_filter=with_filter,
        with_sample=with_sample, block_v=block_v,
        block_b=block_b, n_buckets=n_buckets,
        labels=labels, exclude=exclude)
