"""Pure-jnp oracles for every kernel in this package.

These are the ground-truth implementations the Pallas kernels are tested
against (``tests/test_kernels_cce.py`` sweeps shapes/dtypes and asserts
allclose). They intentionally materialize the full ``(N, V)`` logit matrix —
that is the memory blow-up CCE removes — so keep test sizes modest.

Conventions (used across the whole repo):
  E : (N, D)  token embeddings (backbone output).
  C : (V, D)  classifier / unembedding matrix (row-major vocab).
  x : (N,)    int32 labels in [0, V) or ``ignore_index``.
  softcap : optional float t, logits are ``t * tanh(z / t)`` (Gemma-2).

All reductions are performed in float32 regardless of input dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE_INDEX = -100


def apply_softcap(logits: jax.Array, softcap: float | None) -> jax.Array:
    if softcap is None:
        return logits
    return softcap * jnp.tanh(logits / softcap)


def ref_logits(E: jax.Array, C: jax.Array, softcap: float | None = None) -> jax.Array:
    """Full (N, V) logit matrix in f32 (the object CCE never materializes)."""
    z = jnp.dot(E.astype(jnp.float32), C.astype(jnp.float32).T)
    return apply_softcap(z, softcap)


def ref_indexed_matmul(E: jax.Array, C: jax.Array, x: jax.Array,
                       softcap: float | None = None) -> jax.Array:
    """o_i = softcap(C[x_i] . E_i)   — Algorithm 1 oracle, O(N*D) memory."""
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    picked = jnp.take(C, safe_x, axis=0).astype(jnp.float32)  # (N, D)
    o = jnp.sum(picked * E.astype(jnp.float32), axis=-1)
    return apply_softcap(o, softcap)


def ref_lse(E: jax.Array, C: jax.Array, softcap: float | None = None) -> jax.Array:
    """LSE_i = log sum_j exp(logits[i, j])   — Algorithm 2 oracle."""
    z = ref_logits(E, C, softcap)
    return jax.scipy.special.logsumexp(z, axis=-1)


def ref_linear_cross_entropy(E: jax.Array, C: jax.Array, x: jax.Array,
                             softcap: float | None = None) -> jax.Array:
    """Per-token negative log-likelihood; 0.0 at ignored positions.

    nll_i = LSE_i - logits[i, x_i]
    """
    z = ref_logits(E, C, softcap)
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    picked = jnp.take_along_axis(z, safe_x[:, None], axis=-1)[:, 0]
    nll = lse - picked
    return jnp.where(x == IGNORE_INDEX, 0.0, nll)


def ref_mean_nll(E, C, x, softcap=None):
    nll = ref_linear_cross_entropy(E, C, x, softcap)
    valid = (x != IGNORE_INDEX).astype(jnp.float32)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1.0)


def ref_grads(E, C, x, softcap=None, g=None):
    """(dE, dC) for sum(g * nll); g defaults to ones. Computed by autodiff
    of the dense formulation — the gold standard the Pallas backward kernels
    must match."""
    if g is None:
        g = jnp.ones((E.shape[0],), jnp.float32)

    def loss(e, c):
        return jnp.sum(ref_linear_cross_entropy(e, c, x, softcap) * g)

    return jax.grad(loss, argnums=(0, 1))(E, C)


def ref_softmax(E, C, lse=None, softcap=None):
    """S = exp(logits - LSE)  (N, V), used by sparsity analyses/benchmarks."""
    z = ref_logits(E, C, softcap)
    if lse is None:
        lse = jax.scipy.special.logsumexp(z, axis=-1)
    return jnp.exp(z - lse[:, None])


def ref_avg_logit(E, C, softcap: float | None = None) -> jax.Array:
    """Average logit per vocab entry over tokens — the vocabulary-sorting key.

    The paper accumulates this with atomics during the forward pass. Because
    the mean over tokens commutes with the linear map, avg_v = C_v . mean(E)
    (exact for softcap=None; for softcapped models the kernel sorts by the
    *pre-cap* average which preserves order since tanh is monotone).
    """
    del softcap  # monotone => ordering identical; sorting is heuristic anyway
    return jnp.dot(C.astype(jnp.float32), jnp.mean(E.astype(jnp.float32), axis=0))


def ref_block_live(E, C, x, block_n: int, block_v: int, eps: float,
                   softcap: float | None = None):
    """Block-granular gradient-filtering oracle (paper Alg. 4): boolean
    ``(cdiv(N, block_n), cdiv(V, block_v))`` map, True where
    ``max |S - onehot| >= eps`` over the block — what the recompute
    statistic keeps, and the set the fwd-emitted bitmap must cover
    (its conservative superset additionally keeps every label block)."""
    import numpy as np

    safe_x = np.asarray(jnp.where(x == IGNORE_INDEX, 0, x))
    S = np.asarray(ref_softmax(E, C, softcap=softcap))
    onehot = np.zeros_like(S)
    onehot[np.arange(S.shape[0]), safe_x] = 1.0
    stat = np.abs(S - onehot)
    n, v = stat.shape
    nn, nv = -(-n // block_n), -(-v // block_v)
    out = np.zeros((nn, nv), bool)
    for nb in range(nn):
        for vb in range(nv):
            out[nb, vb] = stat[nb * block_n:(nb + 1) * block_n,
                               vb * block_v:(vb + 1) * block_v].max() >= eps
    return out


def peaked_problem(n, d, v, hot=64, scale=22.0, seed=11, noise=0.05):
    """(E, C, x, g) with post-training-like softmax concentration, so
    gradient filtering genuinely skips blocks: confident predictions
    (E ~ scale * C[x]) of Zipf-ish labels drawn from a small hot set.
    Random E/C give near-uniform softmax ~1/V > eps and nothing filters —
    tests and benchmarks of the filtering/bitmap paths share this
    generator instead of re-tuning the concentration by hand."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.randint(ks[0], (n,), 0, hot)
    C = (jax.random.normal(ks[1], (v, d)) * (d ** -0.5)).astype(jnp.float32)
    E = C[x] * scale + jax.random.normal(ks[2], (n, d)) * noise
    g = jax.random.normal(ks[3], (n,))
    return E, C, x, g


def ref_wkv(r, k, v, w_log, u, state0):
    """Sequential (per-token) RWKV-6 WKV oracle — O(S) python loop, f32.

    r/k/v/w_log: (B, H, S, hd); u: (H, hd); state0: (B, H, hd, hd).
    Returns (out (B,H,S,hd), final state). Matches the chunked twin
    (models/recurrent._rwkv6_chunk) and the Pallas kernel (kernels/wkv.py).
    """
    r, k, v = (t.astype(jnp.float32) for t in (r, k, v))
    w_log = w_log.astype(jnp.float32)
    St = state0.astype(jnp.float32)
    outs = []
    for t in range(r.shape[2]):
        kt, vt, rt = k[:, :, t], v[:, :, t], r[:, :, t]
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        o = (jnp.einsum("bhd,bhde->bhe", rt, St)
             + jnp.einsum("bhd,bhde->bhe", rt * u[None], kv))
        St = jnp.exp(w_log[:, :, t])[..., None] * St + kv
        outs.append(o)
    return jnp.stack(outs, 2), St
