"""Shared helpers for the Pallas kernel wrappers."""

from __future__ import annotations

import jax

#: Single source of truth for the per-core VMEM working-set budget.
#: ~12 MB of the ~16 MB/core VMEM; the rest is double-buffering headroom
#: for the Pallas pipeline. Both the CCE block chooser (`kernels/ops`) and
#: the decode-kernel accounting (`kernels/decode_sample`) budget against
#: this constant, and `repro.analysis.checks` verifies every kernel's
#: statically-extracted working set against it.
VMEM_BUDGET = 12 * 1024 * 1024

# ``jax.typeof`` and avals with a ``vma`` field only exist on newer JAX
# releases (the explicit varying-manual-axes machinery). On older JAX the
# checker that needs the annotation does not exist either, so the empty
# set is both the only expressible and the correct answer.
_TYPEOF = getattr(jax, "typeof", None)


def compiler_params(**kwargs):
    """TPU compiler params across JAX versions (renamed TPUCompilerParams ->
    CompilerParams upstream)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def out_vma(*arrays) -> frozenset:
    """Union of the inputs' varying-manual-axes types.

    Inside ``jax.shard_map`` (check_vma=True), ``pl.pallas_call`` outputs
    must declare how they vary across mesh axes; kernel outputs vary over
    every axis any input varies over. Outside shard_map this is the empty
    set, which is equally valid.
    """
    vma: set = set()
    if _TYPEOF is not None:
        for a in arrays:
            t = _TYPEOF(a)
            vma |= set(getattr(t, "vma", ()) or ())
    return frozenset(vma)


def sds(shape, dtype, *arrays) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct carrying the vma union of ``arrays``."""
    vma = out_vma(*arrays)
    if not vma:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
