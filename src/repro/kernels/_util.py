"""Shared helpers for the Pallas kernel wrappers."""

from __future__ import annotations

import jax


def out_vma(*arrays) -> frozenset:
    """Union of the inputs' varying-manual-axes types.

    Inside ``jax.shard_map`` (check_vma=True), ``pl.pallas_call`` outputs
    must declare how they vary across mesh axes; kernel outputs vary over
    every axis any input varies over. Outside shard_map this is the empty
    set, which is equally valid.
    """
    vma: set = set()
    for a in arrays:
        t = jax.typeof(a)
        vma |= set(getattr(t, "vma", ()) or ())
    return frozenset(vma)


def sds(shape, dtype, *arrays) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct carrying the vma union of ``arrays``."""
    return jax.ShapeDtypeStruct(shape, dtype, vma=out_vma(*arrays))
