"""Jit-ready wrappers assembling the CCE Pallas kernels into differentiable
ops, plus block-size heuristics and the vocabulary-sorting wrapper.

The core primitive is :func:`lse_and_pick_pallas` — for every token it
returns ``(lse_i, pick_i)`` where ``lse_i = logsumexp_v softcap(C_v . E_i)``
and ``pick_i = softcap(C[x_i] . E_i)``. Its custom VJP accepts arbitrary
cotangents ``(g_lse, g_pick)``, so both the plain NLL loss
(``nll = lse - pick``) and the distributed vocab-parallel combination
(``repro.core.vocab_parallel``) differentiate through it for free.

Public entry point for the loss: :func:`linear_cross_entropy_pallas`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels import cce_bwd, cce_fwd
from repro.kernels.cce_bwd import DEFAULT_FILTER_EPS
from repro.kernels.ref import IGNORE_INDEX

# ~12 MB of the ~16 MB/core VMEM budget for kernel working set; the rest is
# double-buffering headroom for the Pallas pipeline.
_VMEM_BUDGET = 12 * 1024 * 1024


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@dataclasses.dataclass(frozen=True)
class CCEConfig:
    """Static (hashable) configuration for the CCE kernels.

    filter_mode_e / filter_mode_c:
      "filtered" — paper's gradient filtering (eps = 2^-12), default.
      "full"     — no filtering. ``filter_mode_c="full"`` == CCE-*-FullC,
                   the paper's recommended pretraining setting.
    accum: "f32" (TPU-native default) | "bf16_kahan" (paper CCE-Kahan parity)
           | "bf16" (paper's raw CCE accumulation, for ablation only).
    sort_vocab: permute C by descending average logit before the backward
           passes so hot tokens cluster into dense blocks (paper §4.3).
    """
    softcap: float | None = None
    block_n: int | None = None
    block_v: int | None = None
    filter_eps: float = DEFAULT_FILTER_EPS
    filter_mode_e: str = "filtered"
    filter_mode_c: str = "filtered"
    accum: str = "f32"
    sort_vocab: bool = False
    interpret: bool | None = None  # None = auto (interpret on CPU)

    def resolved_interpret(self) -> bool:
        return _is_cpu() if self.interpret is None else self.interpret


def choose_blocks(n_tokens: int, vocab: int, d: int, itemsize: int,
                  accum_rows: int = 1) -> tuple[int, int]:
    """Pick (block_n, block_v): multiples of the (8,128) TPU tile, working
    set under the VMEM budget. Working set per grid step (input tiles are
    double-buffered by the pipeline):

        2*(block_n*D + block_v*D)*itemsize          E/C tiles
      + block_n*block_v*4                           logit tile (f32)
      + accum_rows*max(block_n,block_v)*D*4         f32 accumulator scratch
    """
    def fits(bn, bv):
        ws = (2 * (bn + bv) * d * itemsize + bn * bv * 4
              + accum_rows * max(bn, bv) * d * 4)
        return ws <= _VMEM_BUDGET

    bn, bv = 256, 512
    while bv > 128 and not fits(bn, bv):
        bv //= 2
    while bn > 32 and not fits(bn, bv):
        bn //= 2
    bn = max(8, min(bn, _round_up(n_tokens, 8)))
    bv = max(128, min(bv, _round_up(vocab, 128)))
    return bn, bv


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _resolve_blocks(cfg: CCEConfig, n_tokens, vocab, d, itemsize,
                    accum_rows: int = 1):
    if cfg.block_n is not None and cfg.block_v is not None:
        return cfg.block_n, cfg.block_v
    bn, bv = choose_blocks(n_tokens, vocab, d, itemsize, accum_rows)
    return cfg.block_n or bn, cfg.block_v or bv


# ----------------------------------------------------------------------------
# The differentiable (lse, pick[, sum_logits]) primitive.
#
# ``want_sum`` is a *static* argument: the False path compiles exactly the
# two-output kernels (no dead sum accumulator), the True path adds the
# per-token sum of (softcapped) logits as a third differentiable output —
# the ingredient label smoothing needs (mean logit = sum_logits / V).
# ----------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lse_pick(cfg: CCEConfig, want_sum: bool, E, C, x):
    return _lse_pick_fwd_impl(cfg, want_sum, E, C, x)


def _lse_pick_fwd_impl(cfg, want_sum, E, C, x):
    n_tokens, d = E.shape
    vocab = C.shape[0]
    bn, bv = _resolve_blocks(cfg, n_tokens, vocab, d, E.dtype.itemsize)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    return cce_fwd.cce_forward_pallas(
        E, C, safe_x, softcap=cfg.softcap, block_n=bn, block_v=bv,
        with_sum=want_sum, interpret=cfg.resolved_interpret())


def _lse_pick_vjp_fwd(cfg, want_sum, E, C, x):
    outs = _lse_pick_fwd_impl(cfg, want_sum, E, C, x)
    return outs, (E, C, x, outs[0])


def _lse_pick_vjp_bwd(cfg, want_sum, residuals, cotangents):
    E, C, x, lse = residuals
    g_lse, g_pick = cotangents[0], cotangents[1]
    g_sum = cotangents[2].astype(jnp.float32) if want_sum else None
    n_tokens, d = E.shape
    vocab = C.shape[0]
    bn, bv = _resolve_blocks(cfg, n_tokens, vocab, d, E.dtype.itemsize)
    interpret = cfg.resolved_interpret()
    g_lse = g_lse.astype(jnp.float32)
    g_pick = g_pick.astype(jnp.float32)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)

    # The sum_logits cotangent is dense over the vocabulary (d sum / d a = 1
    # everywhere), so the |S - onehot| block-skip statistic cannot see it —
    # gradient filtering must be off whenever the third output is in use.
    eps_e = (cfg.filter_eps
             if cfg.filter_mode_e == "filtered" and not want_sum else None)
    eps_c = (cfg.filter_eps
             if cfg.filter_mode_c == "filtered" and not want_sum else None)

    if cfg.sort_vocab:
        # Vocabulary sorting (paper §4.3): order vocab by average logit so
        # non-trivial softmax mass clusters into few blocks. avg-logit has
        # the closed form C @ mean(E) — see DESIGN.md §2 (no atomics needed).
        avg = jnp.dot(C.astype(jnp.float32), jnp.mean(E.astype(jnp.float32), 0))
        perm = jnp.argsort(-avg)
        inv_perm = jnp.argsort(perm)
        C_s = jnp.take(C, perm, axis=0)
        x_s = jnp.take(inv_perm, safe_x)
    else:
        perm = inv_perm = None
        C_s, x_s = C, safe_x

    kw = dict(softcap=cfg.softcap, block_n=bn, block_v=bv,
              accum=cfg.accum, interpret=interpret, g_sum=g_sum)
    dE = cce_bwd.cce_backward_dE_pallas(E, C_s, x_s, lse, g_lse, g_pick,
                                        filter_eps=eps_e, **kw)
    dC_s = cce_bwd.cce_backward_dC_pallas(E, C_s, x_s, lse, g_lse, g_pick,
                                          filter_eps=eps_c, **kw)
    dC = jnp.take(dC_s, inv_perm, axis=0) if perm is not None else dC_s
    return dE, dC, None


_lse_pick.defvjp(_lse_pick_vjp_fwd, _lse_pick_vjp_bwd)


def _flatten_call(E, C, x, cfg, want_sum):
    orig_shape = x.shape
    if E.ndim == 3:  # (B, S, D) convenience
        E = E.reshape(-1, E.shape[-1])
        x = x.reshape(-1)
    outs = _lse_pick(cfg, want_sum, E, C, x)
    return tuple(o.reshape(orig_shape) for o in outs)


def lse_and_pick_pallas(E, C, x, cfg: CCEConfig | None = None, **overrides):
    """(lse, pick) f32 vectors of shape x.shape; differentiable in E and C.

    ``x == IGNORE_INDEX`` positions are evaluated against vocab entry 0 —
    callers mask the loss, which zeroes the gradient automatically.
    """
    cfg = dataclasses.replace(cfg or CCEConfig(), **overrides)
    return _flatten_call(E, C, x, cfg, False)


def lse_pick_sum_pallas(E, C, x, cfg: CCEConfig | None = None, **overrides):
    """(lse, pick, sum_logits) — the three-output primitive. sum_logits_i is
    the sum of (softcapped) logits of token i over the whole vocabulary;
    with it, losses over the *uniform* target distribution (label smoothing)
    stay in CCE's O(N) memory class. Gradient filtering is disabled in the
    backward (the sum cotangent is dense — see _lse_pick_vjp_bwd)."""
    cfg = dataclasses.replace(cfg or CCEConfig(), **overrides)
    return _flatten_call(E, C, x, cfg, True)


def linear_cross_entropy_pallas(E, C, x, cfg: CCEConfig | None = None,
                                **overrides):
    """Per-token NLL, shape x.shape, f32, via the CCE Pallas kernels;
    differentiable w.r.t. E and C. Positions with ``x == IGNORE_INDEX`` get
    loss 0 and contribute no gradient.
    """
    lse, pick = lse_and_pick_pallas(E, C, x, cfg, **overrides)
    return jnp.where(x == IGNORE_INDEX, 0.0, lse - pick)
