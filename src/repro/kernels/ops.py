"""Jit-ready wrappers assembling the CCE Pallas kernels into differentiable
ops, plus block-size heuristics and the vocabulary-sorting wrapper.

The core primitive is :func:`lse_and_pick_pallas` — for every token it
returns ``(lse_i, pick_i)`` where ``lse_i = logsumexp_v softcap(C_v . E_i)``
and ``pick_i = softcap(C[x_i] . E_i)``. Its custom VJP accepts arbitrary
cotangents ``(g_lse, g_pick)``, so both the plain NLL loss
(``nll = lse - pick``) and the distributed vocab-parallel combination
(``repro.core.vocab_parallel``) differentiate through it for free.

Public entry point for the loss: :func:`linear_cross_entropy_pallas`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels import cce_bwd, cce_fwd
from repro.kernels._util import VMEM_BUDGET
from repro.kernels.cce_bwd import DEFAULT_FILTER_EPS
from repro.kernels.ref import IGNORE_INDEX

# Back-compat alias; the canonical constant lives in kernels/_util.py so the
# decode kernel and the static checker share one budget.
_VMEM_BUDGET = VMEM_BUDGET


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@dataclasses.dataclass(frozen=True)
class CCEConfig:
    """Static (hashable) configuration for the CCE kernels.

    filter_mode_e / filter_mode_c:
      "filtered" — paper's gradient filtering (eps = 2^-12), default.
      "full"     — no filtering. ``filter_mode_c="full"`` == CCE-*-FullC,
                   the paper's recommended pretraining setting.
    accum: "f32" (TPU-native default) | "bf16_kahan" (paper CCE-Kahan parity)
           | "bf16" (paper's raw CCE accumulation, for ablation only).
    sort_vocab: permute C by descending average logit before the backward
           passes so hot tokens cluster into dense blocks (paper §4.3).
    bwd: "fused" (default, measured best — benchmarks/tableA2): ONE backward
           pass recomputes each logit tile once and feeds both dE and dC;
           "two_pass" runs the classic dE-then-dC passes (each recomputing
           the tile). Falls back to two_pass when accum != "f32" (the fused
           dC accumulates in an f32 HBM output, which has no Kahan twin).
    filter_stats: where the gradient-filtering block-skip decision comes
           from. "fwd_bitmap" (default, measured best): the forward emits a
           per-(n_block, v_block) live-block bitmap, so dead blocks skip
           the logit-tile *recompute itself*; "recompute": paper Alg. 4 —
           the statistic is evaluated from the recomputed tile, so the
           recompute matmul is paid even on filtered blocks. The bitmap is
           a conservative superset of the recompute statistic (label blocks
           always live), and is automatically disabled when nothing filters:
           sum_logits in use (label smoothing — dense cotangent forces full
           gradients), both filter modes "full", or (fused only) mixed
           filter modes.
    """
    softcap: float | None = None
    block_n: int | None = None
    block_v: int | None = None
    filter_eps: float = DEFAULT_FILTER_EPS
    filter_mode_e: str = "filtered"
    filter_mode_c: str = "filtered"
    accum: str = "f32"
    sort_vocab: bool = False
    bwd: str = "fused"
    filter_stats: str = "fwd_bitmap"
    interpret: bool | None = None  # None = auto (interpret on CPU)

    def __post_init__(self):
        if self.bwd not in ("two_pass", "fused"):
            raise ValueError(
                f"CCEConfig.bwd must be 'two_pass' or 'fused'; got "
                f"{self.bwd!r}")
        if self.filter_stats not in ("recompute", "fwd_bitmap"):
            raise ValueError(
                f"CCEConfig.filter_stats must be 'recompute' or "
                f"'fwd_bitmap'; got {self.filter_stats!r}")
        for side in ("filter_mode_e", "filter_mode_c"):
            if getattr(self, side) not in ("filtered", "full"):
                raise ValueError(
                    f"CCEConfig.{side} must be 'filtered' or 'full'; got "
                    f"{getattr(self, side)!r}")
        if self.accum not in ("f32", "bf16", "bf16_kahan"):
            raise ValueError(
                f"CCEConfig.accum must be 'f32', 'bf16' or 'bf16_kahan'; "
                f"got {self.accum!r}")

    def resolved_interpret(self) -> bool:
        return _is_cpu() if self.interpret is None else self.interpret


def vmem_working_set(block_n: int, block_v: int, d: int, itemsize: int,
                     accum_rows: int = 1, *, with_sum: bool = False,
                     emit_bitmap: bool = False, vocab: int | None = None,
                     kahan: bool = False) -> int:
    """Estimated VMEM bytes one grid step of the CCE kernels keeps live.

        2*(block_n*D + block_v*D)*itemsize          E/C tiles (dbl-buffered)
      + block_n*block_v*4                           logit tile (f32)
      + accum_rows*max(block_n,block_v)*D*4         f32 accumulator scratch
        (x2 under Kahan: the compensation buffer mirrors the accumulator)
      + (n_out+1)*block_n*4                         fwd online-LSE columns
                                                    (m/s/pick[, sum])
      + block_n*cdiv(vocab, block_v)*4              fwd per-row tile maxima
                                                    (bitmap emission only)
      + cdiv(vocab, block_v)*4                      the bitmap row itself

    ``accum_rows=2`` models the fused backward (dE scratch + the resident
    dC output block).
    """
    ws = (2 * (block_n + block_v) * d * itemsize + block_n * block_v * 4
          + accum_rows * max(block_n, block_v) * d * 4
          * (2 if kahan else 1))
    n_out = 3 if with_sum else 2
    ws += (n_out + 1) * block_n * 4
    if emit_bitmap:
        assert vocab is not None
        nv = -(-vocab // block_v)
        ws += block_n * nv * 4 + nv * 4
    return ws


def choose_blocks(n_tokens: int, vocab: int, d: int, itemsize: int,
                  accum_rows: int = 1, *, with_sum: bool = False,
                  emit_bitmap: bool = False,
                  kahan: bool = False) -> tuple[int, int]:
    """Pick (block_n, block_v): multiples of the (8,128) TPU tile, with
    :func:`vmem_working_set` under the VMEM budget. ``with_sum`` /
    ``emit_bitmap`` / ``kahan`` charge the optional scratch and output
    buffers (the sum column, the per-row tile-max staging for the bitmap,
    the Kahan compensation buffer) so enabling a knob can never silently
    overflow VMEM at a block shape chosen without it."""
    def fits(bn, bv):
        return vmem_working_set(
            bn, bv, d, itemsize, accum_rows, with_sum=with_sum,
            emit_bitmap=emit_bitmap, vocab=vocab,
            kahan=kahan) <= _VMEM_BUDGET

    bn, bv = 256, 512
    while bv > 128 and not fits(bn, bv):
        bv //= 2
    while bn > 32 and not fits(bn, bv):
        bn //= 2
    bn = max(8, min(bn, _round_up(n_tokens, 8)))
    bv = max(128, min(bv, _round_up(vocab, 128)))
    return bn, bv


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _resolve_blocks(cfg: CCEConfig, n_tokens, vocab, d, itemsize,
                    want_sum: bool = False):
    """One block choice shared by the forward and both backward flavours —
    the bitmap's block grid must match across passes, so every knob that
    changes any kernel's scratch footprint is charged here."""
    if cfg.block_n is not None and cfg.block_v is not None:
        return cfg.block_n, cfg.block_v
    plan = _bwd_plan(cfg, want_sum)
    bn, bv = choose_blocks(
        n_tokens, vocab, d, itemsize,
        accum_rows=2 if plan.fused else 1,
        with_sum=want_sum, emit_bitmap=plan.emit_bitmap,
        kahan=cfg.accum == "bf16_kahan")
    return cfg.block_n or bn, cfg.block_v or bv


# ----------------------------------------------------------------------------
# The differentiable (lse, pick[, sum_logits]) primitive.
#
# ``want_sum`` is a *static* argument: the False path compiles exactly the
# two-output kernels (no dead sum accumulator), the True path adds the
# per-token sum of (softcapped) logits as a third differentiable output —
# the ingredient label smoothing needs (mean logit = sum_logits / V).
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _BwdPlan:
    """Static backward strategy derived from (CCEConfig, want_sum).

    The sum_logits cotangent is dense over the vocabulary (d sum / d a = 1
    everywhere), so the |S - onehot| block-skip statistic cannot see it —
    gradient filtering (and with it the bitmap) is off whenever the third
    output is in use. The fused path keeps bit-exact two_pass parity only
    under f32 accumulation, and a *shared*-tile skip needs both sides
    filtered, so mixed filter modes fall back to the recompute statistic
    there (two_pass can still bitmap-gate each side independently).
    """
    fused: bool
    eps_e: float | None      # None = that side unfiltered (Full*)
    eps_c: float | None
    bitmap_e: bool           # dE gate comes from the fwd bitmap
    bitmap_c: bool

    @property
    def emit_bitmap(self) -> bool:
        return self.bitmap_e or self.bitmap_c


def _bwd_plan(cfg: CCEConfig, want_sum: bool) -> _BwdPlan:
    eps_e = (cfg.filter_eps
             if cfg.filter_mode_e == "filtered" and not want_sum else None)
    eps_c = (cfg.filter_eps
             if cfg.filter_mode_c == "filtered" and not want_sum else None)
    fused = cfg.bwd == "fused" and cfg.accum == "f32"
    bm = cfg.filter_stats == "fwd_bitmap"
    bitmap_e = bm and eps_e is not None
    bitmap_c = bm and eps_c is not None
    if fused and not (bitmap_e and bitmap_c):
        bitmap_e = bitmap_c = False
    return _BwdPlan(fused=fused, eps_e=eps_e, eps_c=eps_c,
                    bitmap_e=bitmap_e, bitmap_c=bitmap_c)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lse_pick(cfg: CCEConfig, want_sum: bool, E, C, x):
    return _lse_pick_fwd_impl(cfg, want_sum, E, C, x)


def _lse_pick_fwd_impl(cfg, want_sum, E, C, x, emit_bitmap=False):
    n_tokens, d = E.shape
    vocab = C.shape[0]
    bn, bv = _resolve_blocks(cfg, n_tokens, vocab, d, E.dtype.itemsize,
                             want_sum)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    return cce_fwd.cce_forward_pallas(
        E, C, safe_x, softcap=cfg.softcap, block_n=bn, block_v=bv,
        with_sum=want_sum, emit_bitmap=emit_bitmap,
        filter_eps=cfg.filter_eps if emit_bitmap else None,
        interpret=cfg.resolved_interpret())


def _lse_pick_vjp_fwd(cfg, want_sum, E, C, x):
    plan = _bwd_plan(cfg, want_sum)
    outs = _lse_pick_fwd_impl(cfg, want_sum, E, C, x,
                              emit_bitmap=plan.emit_bitmap)
    if plan.emit_bitmap:
        *outs, bitmap = outs
        outs = tuple(outs)
    else:
        bitmap = None
    return outs, (E, C, x, outs[0], bitmap)


def _permute_bitmap(bitmap, perm, vocab, block_v):
    """Re-block the live-block bitmap's v axis under a row permutation of C.

    The permutation is row-granular while the bitmap is block-granular, so
    the exact sorted-layout statistic is unknowable from the bitmap alone.
    Conservative (superset) expansion keeps correctness: a vocab row
    inherits its *source* block's liveness, and a sorted block is live iff
    any of its rows is — so any entry the recompute statistic could keep in
    the sorted layout still lands in a live block. See DESIGN.md §7.
    """
    nn, nv = bitmap.shape
    row_live = jnp.take(bitmap != 0, jnp.arange(vocab) // block_v,
                        axis=1)                       # (nn, V) source blocks
    row_live = jnp.take(row_live, perm, axis=1)       # sorted row order
    pad = nv * block_v - vocab
    if pad:
        row_live = jnp.pad(row_live, ((0, 0), (0, pad)))
    return jnp.max(row_live.reshape(nn, nv, block_v).astype(jnp.int32),
                   axis=2)


def _lse_pick_vjp_bwd(cfg, want_sum, residuals, cotangents):
    E, C, x, lse, bitmap = residuals
    g_lse, g_pick = cotangents[0], cotangents[1]
    g_sum = cotangents[2].astype(jnp.float32) if want_sum else None
    n_tokens, d = E.shape
    vocab = C.shape[0]
    bn, bv = _resolve_blocks(cfg, n_tokens, vocab, d, E.dtype.itemsize,
                             want_sum)
    interpret = cfg.resolved_interpret()
    g_lse = g_lse.astype(jnp.float32)
    g_pick = g_pick.astype(jnp.float32)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    plan = _bwd_plan(cfg, want_sum)

    if cfg.sort_vocab:
        # Vocabulary sorting (paper §4.3): order vocab by average logit so
        # non-trivial softmax mass clusters into few blocks. avg-logit has
        # the closed form C @ mean(E) — see DESIGN.md §2 (no atomics needed).
        avg = jnp.dot(C.astype(jnp.float32), jnp.mean(E.astype(jnp.float32), 0))
        perm = jnp.argsort(-avg)
        inv_perm = jnp.argsort(perm)
        C_s = jnp.take(C, perm, axis=0)
        x_s = jnp.take(inv_perm, safe_x)
        if bitmap is not None:
            bitmap = _permute_bitmap(bitmap, perm, vocab, bv)
    else:
        perm = inv_perm = None
        C_s, x_s = C, safe_x

    kw = dict(softcap=cfg.softcap, block_n=bn, block_v=bv,
              interpret=interpret, g_sum=g_sum)
    # On the compiled target the fused dC flush-before-revisit guard needs
    # enough vocab blocks between revisits (no pipeline in interpret mode).
    run_fused = plan.fused and (
        interpret or -(-vocab // bv) >= cce_bwd.FUSED_MIN_NV)
    if run_fused:
        dE, dC_s = cce_bwd.cce_backward_fused_pallas(
            E, C_s, x_s, lse, g_lse, g_pick,
            filter_eps_e=plan.eps_e, filter_eps_c=plan.eps_c,
            bitmap=bitmap if plan.emit_bitmap else None, **kw)
        dC_s = dC_s.astype(C.dtype)
    else:
        dE = cce_bwd.cce_backward_dE_pallas(
            E, C_s, x_s, lse, g_lse, g_pick, filter_eps=plan.eps_e,
            accum=cfg.accum, bitmap=bitmap if plan.bitmap_e else None, **kw)
        dC_s = cce_bwd.cce_backward_dC_pallas(
            E, C_s, x_s, lse, g_lse, g_pick, filter_eps=plan.eps_c,
            accum=cfg.accum, bitmap=bitmap if plan.bitmap_c else None, **kw)
    dC = jnp.take(dC_s, inv_perm, axis=0) if perm is not None else dC_s
    return dE, dC, None


_lse_pick.defvjp(_lse_pick_vjp_fwd, _lse_pick_vjp_bwd)


def _flatten_call(E, C, x, cfg, want_sum):
    orig_shape = x.shape
    if E.ndim == 3:  # (B, S, D) convenience
        E = E.reshape(-1, E.shape[-1])
        x = x.reshape(-1)
    outs = _lse_pick(cfg, want_sum, E, C, x)
    return tuple(o.reshape(orig_shape) for o in outs)


def lse_and_pick_pallas(E, C, x, cfg: CCEConfig | None = None, **overrides):
    """(lse, pick) f32 vectors of shape x.shape; differentiable in E and C.

    ``x == IGNORE_INDEX`` positions are evaluated against vocab entry 0 —
    callers mask the loss, which zeroes the gradient automatically.
    """
    cfg = dataclasses.replace(cfg or CCEConfig(), **overrides)
    return _flatten_call(E, C, x, cfg, False)


def lse_pick_sum_pallas(E, C, x, cfg: CCEConfig | None = None, **overrides):
    """(lse, pick, sum_logits) — the three-output primitive. sum_logits_i is
    the sum of (softcapped) logits of token i over the whole vocabulary;
    with it, losses over the *uniform* target distribution (label smoothing)
    stay in CCE's O(N) memory class. Gradient filtering is disabled in the
    backward (the sum cotangent is dense — see _lse_pick_vjp_bwd)."""
    cfg = dataclasses.replace(cfg or CCEConfig(), **overrides)
    return _flatten_call(E, C, x, cfg, True)


# ----------------------------------------------------------------------------
# Kernel observables (repro.obs): the quantities the paper plots, exposed as
# cheap probes a metrics registry can gauge — live-block fraction (Fig. 3's
# softmax sparsity as a live training metric), the resolved block plan, and
# its VMEM working set.
# ----------------------------------------------------------------------------

def kernel_plan(n_tokens: int, vocab: int, d: int, itemsize: int = 4,
                cfg: CCEConfig | None = None,
                want_sum: bool = False) -> dict:
    """The static execution plan the kernels would use at this geometry:
    resolved ``(block_n, block_v)``, backward strategy, and the VMEM
    working set :func:`choose_blocks` charged — what
    ``repro.obs.kernels.record_cce_gauges`` exports."""
    cfg = cfg or CCEConfig()
    plan = _bwd_plan(cfg, want_sum)
    bn, bv = _resolve_blocks(cfg, n_tokens, vocab, d, itemsize, want_sum)
    ws = vmem_working_set(
        bn, bv, d, itemsize, accum_rows=2 if plan.fused else 1,
        with_sum=want_sum, emit_bitmap=plan.emit_bitmap, vocab=vocab,
        kahan=cfg.accum == "bf16_kahan")
    return {"block_n": bn, "block_v": bv, "fused": plan.fused,
            "emit_bitmap": plan.emit_bitmap,
            "vmem_working_set_bytes": ws,
            "vmem_budget_bytes": _VMEM_BUDGET}


def live_block_bitmap(E, C, x, cfg: CCEConfig | None = None):
    """Run the forward kernel with bitmap emission and return
    ``(bitmap, (block_n, block_v))`` — ``bitmap`` a boolean
    ``(cdiv(N, block_n), cdiv(V, block_v))`` array, True where the
    backward would visit the block (the conservative superset of paper
    Alg. 4's ``max|S - onehot| >= eps`` statistic; see DESIGN.md §7).

    ``bitmap.mean()`` is the live-block fraction — paper Fig. 3's softmax
    sparsity, observable during training without materializing softmax.
    """
    cfg = cfg or CCEConfig()
    if E.ndim == 3:
        E = E.reshape(-1, E.shape[-1])
        x = x.reshape(-1)
    n_tokens, d = E.shape
    vocab = C.shape[0]
    bn, bv = _resolve_blocks(cfg, n_tokens, vocab, d, E.dtype.itemsize)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    *_, bitmap = cce_fwd.cce_forward_pallas(
        E, C, safe_x, softcap=cfg.softcap, block_n=bn, block_v=bv,
        emit_bitmap=True, filter_eps=cfg.filter_eps,
        interpret=cfg.resolved_interpret())
    return bitmap != 0, (bn, bv)


def linear_cross_entropy_pallas(E, C, x, cfg: CCEConfig | None = None,
                                **overrides):
    """Per-token NLL, shape x.shape, f32, via the CCE Pallas kernels;
    differentiable w.r.t. E and C. Positions with ``x == IGNORE_INDEX`` get
    loss 0 and contribute no gradient.
    """
    lse, pick = lse_and_pick_pallas(E, C, x, cfg, **overrides)
    return jnp.where(x == IGNORE_INDEX, 0.0, lse - pick)
