"""Cross-version JAX compatibility shims.

The repo targets the modern JAX surface (``jax.shard_map``, explicit
``AxisType`` meshes, the varying-manual-axes checker and ``jax.lax.pcast``)
but must also run on older releases where those names either live elsewhere
(``jax.experimental.shard_map``), take different keywords (``check_rep`` vs
``check_vma``) or do not exist at all (``pcast``/``AxisType`` — the vma
system itself is absent, so there is nothing to declare and the shims are
no-ops there).
"""

from __future__ import annotations

import numpy as np

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    On old JAX the vma checker does not exist; ``check_vma`` maps onto
    ``check_rep=False`` so that shard_map's pessimistic transpose inserts
    the replication psums itself — correct (if occasionally redundant)
    gradients on every version.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def pcast_varying(x, axes):
    """Mark ``x`` device-varying over mesh ``axes`` where the vma system
    exists; identity elsewhere (old shard_map treats everything as varying).
    """
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, tuple(axes), to="varying")


def _axis_types(n):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the concept exists."""
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def mesh_from_devices(devices, shape, axes):
    """Explicit-device Mesh (e.g. a subset of forced host devices)."""
    from jax.sharding import Mesh
    dev = np.asarray(devices).reshape(shape)
    try:
        return Mesh(dev, axes, **_axis_types(len(axes)))
    except TypeError:  # old Mesh: no axis_types kwarg
        return Mesh(dev, axes)
