"""Serving CLI: batched greedy generation with a reduced-config model.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --prompts "1,2,3;4,5" --max-new 8 [--batch-size 8]
"""

import argparse
import dataclasses
import sys

import jax

import repro.configs as configs
from repro.models import transformer as T
from repro.serve import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompts", default="1,2,3;4,5,6,7")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="engine batch capacity (rows per decode step)")
    args = ap.parse_args()

    prompts = [[int(t) for t in p.split(",")]
               for p in args.prompts.split(";") if p.strip()]
    if not prompts:
        sys.exit("--prompts is empty: pass ';'-separated comma token lists, "
                 "e.g. --prompts '1,2,3;4,5'")
    if len(prompts) > args.batch_size:
        sys.exit(f"{len(prompts)} prompts exceed --batch-size "
                 f"{args.batch_size}: raise --batch-size (one engine row "
                 f"per prompt) or pass fewer prompts")

    cfg = (configs.get_reduced_config(args.arch) if args.reduced
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=args.max_len,
                 batch_size=args.batch_size)
    out = eng.generate(prompts, max_new_tokens=args.max_new)
    for p, o in zip(prompts, out):
        print(f"prompt {p} -> {o}")


if __name__ == "__main__":
    main()
