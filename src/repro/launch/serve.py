"""Serving CLI: continuous-batching decode and CCE-backed scoring.

Decode (default mode) — sampled generation over the slot scheduler:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --prompts "1,2,3;4,5" --max-new 8 [--batch-size 8] \
      [--prefill-chunk 16] [--temperature 0.8] [--top-k 40] [--top-p 0.9] \
      [--seed 0] [--eos 2]

  ``--prefill-chunk N`` ingests prompts N tokens per step (chunked
  prefill, fused with decode of the other rows) — lower TTFT, identical
  tokens.

  Speculative decoding: ``--spec-k K`` drafts K tokens per row per step
  (zero-cost n-gram/prompt-lookup drafter by default, or a small draft
  transformer via ``--draft-arch``) and verifies them with ONE fused
  logit-free sweep — up to K+1 tokens per target step, still one host
  sync per step. Greedy streams are token-identical to plain decode;
  sampled streams draw from the same per-row distribution
  (accept-ratio test + residual bonus sampling, DESIGN.md §12).

  Request streams: --requests FILE reads one JSON object per line
      {"prompt": [1,2,3], "max_new": 8, "temperature": 0.8, "top_k": 40,
       "top_p": 0.9, "seed": 1, "eos": 2, "arrive_step": 4}
  and submits each request when the engine reaches its ``arrive_step`` —
  requests join mid-flight, finished rows leave and their slot is reused.

  Shared prefixes: a definition line {"prefix_id": "sys", "prefix":
  [5,6,7]} names a token prefix; a request line carrying {"prefix_id":
  "sys", ...} gets it prepended to its prompt. With paging enabled
  (below) requests sharing a prefix reuse its page-aligned KV pages
  copy-free instead of re-prefilling them.

  Paged KV cache: ``--kv-page-size P`` switches the full-attention KV
  layout from dense per-slot rows to a block-paged pool
  (repro.serve.kvpool) of ``--kv-pages`` pages (default: the dense-
  equivalent batch_size * ceil(max_len / P)). Admission gains a
  page-budget gate; shared page-aligned prompt prefixes are refcounted
  and reused copy-free. Default off (dense layout).

  Observability: ``--metrics-jsonl trace.jsonl`` records per-request
  spans (submit -> retire, with slot/TTFT attribution), queue/slot
  gauges, TTFT + inter-token-latency histograms and a final metrics
  snapshot — all derived from the engine's existing one-sync-per-step
  status pull, so enabling it adds zero device transfers (asserted by
  tests/test_serve.py). ``--metrics-port N`` additionally serves live
  Prometheus text at ``/metrics``.

Scoring (--score) — rank candidate completions by log p(completion|prompt)
through the CCE primitive (no (B, S, V) logits at any point):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --score --prompt "1,2,3" --completions "4,5;6,7;8" \
      [--normalize tokens|sum] [--score-impl cce_jax] [--check-memory-class]

``--check-memory-class`` additionally lowers the scorer and fails (exit 1)
if its optimized HLO contains any buffer in the N×V memory class — the CI
smoke gate for the serving path, mirroring benchmarks/loss_zoo_memory.
"""

import argparse
import dataclasses
import json
import sys

import jax

import repro.configs as configs
from repro import backends
from repro.launch.obs_flags import add_obs_args, obs_from_args
from repro.models import transformer as T
from repro.serve import Engine, SamplingParams, scoring


def _parse_tokens(s: str) -> list:
    return [int(t) for t in s.split(",") if t.strip()]


def _parse_prompt_list(s: str) -> list:
    out = [_parse_tokens(p) for p in s.split(";") if p.strip()]
    if not out:
        sys.exit("empty prompt list: pass ';'-separated comma token lists, "
                 "e.g. '1,2,3;4,5'")
    return out


def _load_requests(path: str) -> list:
    """JSONL request stream -> [(arrive_step, kwargs)] sorted by arrival.

    Lines with a "prefix" token list *define* a named shared prefix
    ({"prefix_id": "sys", "prefix": [...]}); request lines referencing a
    "prefix_id" get that prefix prepended to their prompt. Definitions
    apply in file order and must precede their first use.
    """
    reqs, prefixes = [], {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                r = json.loads(line)
            except json.JSONDecodeError as e:
                sys.exit(f"{path}:{ln}: not valid JSON ({e})")
            if "prefix" in r:
                if not isinstance(r["prefix"], list):
                    sys.exit(f"{path}:{ln}: 'prefix' must be a token list")
                if "prefix_id" not in r:
                    sys.exit(f"{path}:{ln}: a prefix definition needs a "
                             f"'prefix_id' name")
                prefixes[str(r["prefix_id"])] = [int(t) for t in
                                                 r["prefix"]]
                continue
            if "prompt" not in r or not isinstance(r["prompt"], list):
                sys.exit(f"{path}:{ln}: each request needs a 'prompt' "
                         f"token list")
            if "prefix_id" in r:
                pid = str(r["prefix_id"])
                if pid not in prefixes:
                    sys.exit(f"{path}:{ln}: unknown prefix_id {pid!r} "
                             f"(define it first with a "
                             f'{{"prefix_id": ..., "prefix": [...]}} line)')
                r = dict(r, prompt=prefixes[pid] + list(r["prompt"]))
            reqs.append((int(r.get("arrive_step", 0)), r))
    reqs.sort(key=lambda p: p[0])
    return reqs


def _sampling_of(req: dict, defaults: SamplingParams) -> SamplingParams:
    return SamplingParams(
        temperature=float(req.get("temperature", defaults.temperature)),
        top_k=int(req.get("top_k", defaults.top_k)),
        top_p=float(req.get("top_p", defaults.top_p)),
        seed=int(req.get("seed", defaults.seed)))


def _decode_mode(args, cfg, params):
    if args.sync_every < 1:
        sys.exit(f"--sync-every must be >= 1, got {args.sync_every}")
    if args.prefill_chunk < 1:
        sys.exit(f"--prefill-chunk must be >= 1, got {args.prefill_chunk}")
    if args.kv_pages is not None and args.kv_page_size is None:
        sys.exit("--kv-pages requires --kv-page-size")
    if args.kv_page_size is not None and args.kv_page_size < 1:
        sys.exit(f"--kv-page-size must be >= 1, got {args.kv_page_size}")
    if args.kv_pages is not None and args.kv_pages < 1:
        sys.exit(f"--kv-pages must be >= 1, got {args.kv_pages}")
    if args.spec_k < 0:
        sys.exit(f"--spec-k must be >= 0, got {args.spec_k}")
    if args.spec_k > 0 and args.decode_kernel != "fused":
        sys.exit("--spec-k requires --decode-kernel fused (speculative "
                 "verification runs the fused projection->sample sweep)")
    if args.draft_arch is not None and args.spec_k == 0:
        sys.exit("--draft-arch requires --spec-k > 0")
    draft_cfg = draft_params = None
    if args.draft_arch is not None:
        draft_cfg = (configs.get_reduced_config(args.draft_arch)
                     if args.reduced
                     else configs.get_config(args.draft_arch))
        draft_cfg = dataclasses.replace(draft_cfg, dtype="float32")
        if draft_cfg.vocab_size != cfg.vocab_size:
            sys.exit(f"draft arch {args.draft_arch!r} has vocab "
                     f"{draft_cfg.vocab_size}, target has "
                     f"{cfg.vocab_size}: they must share the vocab")
        draft_params = T.init_lm(jax.random.PRNGKey(args.seed + 1),
                                 draft_cfg)
    metrics, tracer, obs_finish = obs_from_args(args)
    eng = Engine(cfg, params, max_len=args.max_len,
                 batch_size=args.batch_size,
                 prefill_chunk=args.prefill_chunk,
                 metrics=metrics, tracer=tracer,
                 kv_page_size=args.kv_page_size, kv_pages=args.kv_pages,
                 decode_kernel=args.decode_kernel, spec_k=args.spec_k,
                 draft_cfg=draft_cfg, draft_params=draft_params)
    base = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.seed)
    pending = []          # [(arrive_step, submit_kwargs)]
    if args.requests:
        for arrive, r in _load_requests(args.requests):
            pending.append((arrive, dict(
                prompt=r["prompt"],
                max_new_tokens=int(r.get("max_new", args.max_new)),
                sampling=_sampling_of(r, base),
                eos_token=r.get("eos", args.eos))))
    else:
        for p in _parse_prompt_list(args.prompts):
            pending.append((0, dict(prompt=p, max_new_tokens=args.max_new,
                                    sampling=base, eos_token=args.eos)))

    rids, comps, step = {}, {}, 0
    while pending or eng.has_work():
        if pending and not eng.has_work() and pending[0][0] > step:
            step = pending[0][0]     # idle: fast-forward to the next
        while pending and pending[0][0] <= step:
            _, kw = pending.pop(0)
            rids[eng.submit(**kw)] = (step, kw["prompt"])
        for c in eng.step(substeps=args.sync_every):
            comps[c.rid] = c
        step += args.sync_every
    for rid in sorted(rids):
        c = comps[rid]
        arrive, prompt = rids[rid]
        print(f"req {rid} (arrived step {arrive}) prompt {prompt} -> "
              f"{c.tokens}  [{c.finish_reason}]")
    if metrics is not None:
        fin = metrics.total("serve_requests_finished_total")
        gen = metrics.total("serve_generated_tokens_total")
        h = metrics.histogram("serve_ttft_seconds")
        print(f"# telemetry: {fin:.0f} finished, {gen:.0f} tokens "
              f"generated, mean TTFT {1e3 * h.mean:.1f} ms")
        if args.spec_k:
            drafted = metrics.total("serve_spec_draft_tokens_total")
            emitted = metrics.total("serve_spec_emitted_tokens_total")
            ah = metrics.histogram("serve_spec_accepted_len",
                                   {"spec_k": args.spec_k})
            rate = metrics.value("serve_spec_accept_rate") or 0.0
            print(f"# speculative: k={args.spec_k}, {drafted:.0f} "
                  f"drafted, {emitted:.0f} emitted, mean accepted "
                  f"length {ah.mean:.2f}, accept rate {rate:.2f}")
    if eng.pool is not None:
        st = eng.pool.stats()
        print(f"# kvpool: {st['num_pages']} pages x {st['page_size']} "
              f"tok, peak {st['peak_pages']}, prefix pages reused "
              f"{st['reused_pages_total']}/{st['prompt_pages_total']} "
              f"(hit rate {st['prefix_hit_rate']:.2f})")
    obs_finish()
    return 0


def _score_mode(args, cfg, params):
    if cfg.is_encdec:
        sys.exit(f"--score does not support encoder-decoder archs yet "
                 f"({cfg.name}): scoring would need encoder inputs")
    prompt = _parse_tokens(args.prompt)
    comps = _parse_prompt_list(args.completions)
    impl = args.score_impl or cfg.loss_impl
    order, scores = scoring.rank(params, cfg, prompt, comps,
                                 normalize=args.normalize, impl=impl)
    for r, i in enumerate(order):
        print(f"#{r + 1}  logprob({args.normalize})={scores[i]:+.4f}  "
              f"completion {comps[i]}")

    if args.check_memory_class:
        ok = check_scoring_memory_class(cfg, impl=impl,
                                        normalize=args.normalize)
        return 0 if ok else 1
    return 0


def check_scoring_memory_class(cfg, *, impl=None, normalize="sum",
                               batch=8, seq=64, min_vocab=32768,
                               quiet=False) -> bool:
    """AOT-lower the scorer and verify its HLO stays out of the N×V class.

    The vocabulary is enlarged to ``min_vocab`` so the verdict is sharp:
    at smoke-config sizes V is so small that a legitimate (N, block_v)
    kernel tile coincides with N×V. Same budget convention as
    benchmarks/loss_zoo_memory: 4·max(N·D, V·D) elems.
    """
    import dataclasses as _dc

    from repro.analysis.checks.memclass import check_memory_class

    cfg = _dc.replace(cfg, vocab_size=max(cfg.vocab_size, min_vocab))
    d = cfg.d_model
    # the verdict is only discriminating when N·V exceeds the budget:
    # with V >= N that needs N > 4·D, so grow the token count for
    # large-d_model configs instead of passing vacuously (the checker
    # itself raises if the geometry still cannot discriminate)
    seq = max(seq, (4 * d) // batch + 1)
    n, v = batch * seq, cfg.padded_vocab_size
    params_sds = jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    fn = scoring.score_fn(cfg, normalize=normalize,
                          impl=impl or cfg.loss_impl)
    toks = jax.ShapeDtypeStruct((batch, seq), "int32")
    try:
        finding = check_memory_class(jax.jit(fn), params_sds, toks, toks,
                                     n=n, v=v, d=d, what="serve:scoring")
    except ValueError as exc:    # non-discriminating geometry
        raise RuntimeError(str(exc)) from exc
    if not quiet:
        top_elems, top_desc = finding.data["census"][0]
        print(f"scoring memory-class check (B={batch} S={seq} V={v}): "
              f"largest={top_desc} ({top_elems:.3g} elems) "
              f"budget={finding.data['budget_elems']:.3g} "
              f"NxV={n * v:.3g} -> "
              f"{'O(N.D+V.D) OK' if finding.ok else 'NxV MATERIALIZED'}")
    return finding.ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    # decode mode
    ap.add_argument("--prompts", default="1,2,3;4,5,6,7")
    ap.add_argument("--requests", default=None,
                    help="JSONL request stream (see module docstring); "
                         "overrides --prompts")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="engine slots (concurrent rows per decode step)")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="jitted decode steps per host sync")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens ingested per step while a row "
                         "prefills (1 = one-token teacher forcing); "
                         "larger chunks cut TTFT without changing tokens")
    ap.add_argument("--decode-kernel", choices=["fused", "dense"],
                    default="fused",
                    help="fused: logit-free projection->sample kernel "
                         "(never materializes (B, V) logits); dense: "
                         "explicit logits + device sampler (fallback and "
                         "golden oracle)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding draft length (0 = off): "
                         "each step drafts K tokens and verifies them "
                         "with one fused logit-free sweep, emitting up "
                         "to K+1 tokens per step; greedy output is "
                         "token-identical (requires --decode-kernel "
                         "fused)")
    ap.add_argument("--draft-arch", default=None,
                    help="draft-model arch for --spec-k (any config "
                         "sharing the target vocab; honors --reduced); "
                         "default: the zero-cost n-gram/prompt-lookup "
                         "drafter")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0, help="0 = off")
    ap.add_argument("--top-p", type=float, default=1.0, help="1 = off")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos", type=int, default=None,
                    help="stop generation at this token id")
    ap.add_argument("--kv-page-size", type=int, default=None,
                    help="block-paged KV cache: tokens per page "
                         "(default: dense per-slot layout)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="physical pages in the shared KV pool (default: "
                         "dense-equivalent batch_size * ceil(max_len / "
                         "page_size); requires --kv-page-size)")
    # scoring mode
    ap.add_argument("--score", action="store_true",
                    help="rank --completions under --prompt via the "
                         "CCE-backed scorer instead of decoding")
    ap.add_argument("--prompt", default="1,2,3")
    ap.add_argument("--completions", default="4,5;6,7")
    ap.add_argument("--normalize", default="tokens",
                    choices=["tokens", "sum"])
    ap.add_argument("--score-impl", default=None,
                    choices=["auto"] + backends.list_backends())
    ap.add_argument("--check-memory-class", action="store_true",
                    help="fail unless the scorer HLO stays out of the "
                         "N×V memory class (CI gate)")
    add_obs_args(ap)
    args = ap.parse_args()

    cfg = (configs.get_reduced_config(args.arch) if args.reduced
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    if args.score:
        sys.exit(_score_mode(args, cfg, params))
    sys.exit(_decode_mode(args, cfg, params))


if __name__ == "__main__":
    main()
