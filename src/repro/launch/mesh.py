"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run forces 512 host devices (dryrun.py sets XLA_FLAGS before
any import); real launches get the same logical meshes over TPU slices.
"""

from __future__ import annotations

import numpy as np

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return compat.make_mesh(shape, axes)
    if len(devices) > n:   # e.g. 512 forced devices, single-pod mesh
        return compat.mesh_from_devices(devices[:n], shape, axes)
    raise RuntimeError(
        f"need {n} devices for mesh {dict(zip(axes, shape))}, have "
        f"{len(devices)} — the dry-run must set "
        f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
        f"jax import")


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (8 forced host devices)."""
    return compat.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
