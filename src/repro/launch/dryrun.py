import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell and each production mesh,
``jax.jit(step).lower(**input_specs).compile()`` must succeed; we record
``memory_analysis()`` (fits-per-device proof), ``cost_analysis()``, and the
HLO-analyzer roofline terms into one JSON per cell under results/dryrun/.

NOTE the XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init). Do not import this module from test/bench
processes that need a single device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi [--out results/dryrun] [--force]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import backends
from repro.analysis import hlo as hlo_an
from repro.analysis.roofline import roofline
from repro.configs.base import SHAPES, TrainConfig
from repro.launch.cce_flags import add_cce_args, cce_config_from_args
from repro.launch.inputs import serve_specs, supports_shape, train_specs
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw
from repro.sharding import make_rules, use_sharding_rules
from repro.sharding.specs import named, param_specs
from repro.train.trainer import make_train_step


def _train_fn(cfg, mesh, cce_cfg=None):
    """Full production train step (fwd + bwd + AdamW) with the
    vocab-parallel CCE head over the model axis."""
    dp = data_axes_of(mesh)

    # cfg.loss_impl selects the head by capability, not by name: any
    # mesh-capable backend (cce_jax production twin, dense as the Megatron
    # vocab-parallel CE baseline, cce Pallas) runs under the combine;
    # anything else falls back to auto-resolution among those that can.
    req = backends.Requirements(custom_cotangents=True, mesh=True)
    try:
        be = backends.resolve(cfg.loss_impl, requirements=req)
    except backends.BackendResolutionError:
        be = backends.resolve("auto", requirements=req)

    tcfg = TrainConfig(microbatch=cfg.train_microbatch)
    return make_train_step(cfg, tcfg, loss_impl=be.name, mesh=mesh,
                           vocab_axis="model", token_axes=dp,
                           cce_cfg=cce_cfg)


def _serve_fn(cfg):
    def step(params, cache, tokens, cache_index, enc_out=None):
        return T.serve_step(params, cfg, cache, tokens, cache_index,
                            enc_out=enc_out)
    return step


def lower_cell(cfg, shape, mesh, cce_cfg=None):
    """Lower one (config x shape) cell on ``mesh``; returns ``lowered`` or
    None if the shape doesn't apply to this family (long-ctx dense attn)."""
    ok, _ = supports_shape(cfg, shape)
    if not ok:
        return None
    params_sds = jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    p_specs = named(mesh, param_specs(cfg, params_sds, mesh))

    rules = make_rules(mesh, data_axes=data_axes_of(mesh))
    with use_sharding_rules(rules):
        if shape.kind in ("train", "prefill"):
            batch_sds, batch_shard = train_specs(cfg, shape, mesh)
            if shape.kind == "train":
                opt_sds = jax.eval_shape(
                    lambda: adamw.adamw_init(params_sds))
                o_specs = named(mesh, param_specs(cfg, {"m": params_sds,
                                                        "v": params_sds},
                                                  mesh))
                opt_shard = {"m": o_specs["m"], "v": o_specs["v"],
                             "count": jax.sharding.NamedSharding(
                                 mesh, jax.sharding.PartitionSpec())}
                step = _train_fn(cfg, mesh, cce_cfg=cce_cfg)
                return jax.jit(
                    step,
                    in_shardings=(p_specs, opt_shard, batch_shard, None),
                ).lower(params_sds, opt_sds, batch_sds,
                        jax.ShapeDtypeStruct((), jnp.int32))
            # prefill: forward pass producing per-token nll
            def prefill(params, batch):
                return T.train_loss(params, cfg, batch)
            return jax.jit(
                prefill, in_shardings=(p_specs, batch_shard),
            ).lower(params_sds, batch_sds)
        # decode
        args, shard = serve_specs(cfg, shape, mesh)
        fn = _serve_fn(cfg)
        if cfg.is_encdec:
            return jax.jit(fn, in_shardings=(
                p_specs, shard["cache"], shard["tokens"],
                shard["cache_index"], shard["enc_out"])).lower(
                params_sds, args["cache"], args["tokens"],
                args["cache_index"], args["enc_out"])
        return jax.jit(fn, in_shardings=(
            p_specs, shard["cache"], shard["tokens"],
            shard["cache_index"])).lower(
            params_sds, args["cache"], args["tokens"],
            args["cache_index"])


def lower_cell_hlo(arch: str, shape_name: str, *, multi_pod: bool = False,
                   loss_impl: str | None = None) -> str:
    """Compiled post-SPMD HLO text for one cell (analysis tooling)."""
    cfg = configs.get_config(arch)
    if loss_impl:
        cfg = dataclasses.replace(cfg, loss_impl=loss_impl)
    lowered = lower_cell(cfg, SHAPES[shape_name],
                         make_production_mesh(multi_pod=multi_pod))
    if lowered is None:
        raise ValueError(f"{arch} does not support {shape_name}")
    return lowered.compile().as_text()


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, loss_impl: str | None = None,
             tag: str = "", cce_cfg=None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)

    cfg = configs.get_config(arch)
    if loss_impl:
        cfg = dataclasses.replace(cfg, loss_impl=loss_impl)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": chips, "ok": False, "tag": tag}
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, cce_cfg=cce_cfg)
        if lowered is None:
            record["skipped"] = supports_shape(cfg, shape)[1]
            record["ok"] = True
            _dump(path, record)
            return record

        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # old JAX: one dict per device
            cost = cost[0] if cost else {}
        analysis = hlo_an.analyze(compiled.as_text())
        rf = roofline(analysis, chips, cfg, shape, mem)

        record.update({
            "ok": True,
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_total": rf.per_device_bytes,
            },
            "xla_cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
            "hlo": {
                "flops_per_device": analysis["flops"],
                "traffic_bytes_per_device": analysis["traffic_bytes"],
                "collective_bytes_per_device": analysis["collective_bytes"],
                "collective_wire_bytes_per_device":
                    analysis["collective_wire_bytes"],
                "collectives": analysis["collectives"],
                "collective_counts": analysis["collective_counts"],
            },
            "roofline": rf.as_dict(),
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        record["compile_s"] = round(time.time() - t0, 1)
    _dump(path, record)
    return record


def _dump(path, record):
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--loss-impl", default=None,
                    help="override cfg.loss_impl (e.g. dense for baselines)")
    ap.add_argument("--tag", default="", help="suffix for result files")
    add_cce_args(ap)
    args = ap.parse_args()
    cce_cfg = cce_config_from_args(args)

    archs = list(configs.ASSIGNED) if args.arch == "all" \
        else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name == "multi", args.out,
                               force=args.force, loss_impl=args.loss_impl,
                               tag=args.tag, cce_cfg=cce_cfg)
                status = ("SKIP" if rec.get("skipped")
                          else "ok" if rec["ok"] else "FAIL")
                msg = rec.get("error", "")[:120]
                rf = rec.get("roofline", {})
                dom = rf.get("dominant", "")
                print(f"[{status:4s}] {arch:24s} {shape:12s} {mesh_name:6s} "
                      f"{rec.get('compile_s', 0):7.1f}s {dom:10s} {msg}",
                      flush=True)
                n_fail += 0 if rec["ok"] else 1
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
