"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
      --steps 200 --batch 8 --seq 512 [--reduced] [--ckpt DIR] \
      [--loss-impl cce|cce_jax|dense|chunked]

Runs on whatever devices are available; for the production mesh this is
driven by the cluster launcher with one process per host (jax.distributed),
the code paths are identical.
"""

import argparse
import dataclasses

import repro.configs as configs
from repro.configs.base import TrainConfig
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--loss-impl", default=None)
    ap.add_argument("--dtype", default=None)
    args = ap.parse_args()

    cfg = (configs.get_reduced_config(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if args.loss_impl:
        cfg = dataclasses.replace(cfg, loss_impl=args.loss_impl)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    tcfg = TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 20, 1),
                       microbatch=args.microbatch)
    tr = Trainer(cfg, tcfg, checkpoint_dir=args.ckpt, seq_len=args.seq,
                 global_batch=args.batch)
    tr.install_signal_handlers()
    tr.run(num_steps=args.steps)
    if args.ckpt:
        tr.save()


if __name__ == "__main__":
    main()
