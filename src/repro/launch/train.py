"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
      --steps 200 --batch 8 --seq 512 [--reduced] [--ckpt DIR] \
      [--loss-impl auto|cce|cce_jax|dense|chunked|liger] \
      [--loss nll|z_loss|focal|weighted|label_smoothing] \
      [--loss-kwargs '{"eps": 0.1}'] \
      [--cce-sort-vocab] [--cce-filter-mode-e filtered|full] \
      [--cce-filter-mode-c filtered|full] [--cce-accum f32|bf16_kahan|bf16] \
      [--cce-bwd two_pass|fused] [--cce-filter-stats recompute|fwd_bitmap] \
      [--metrics-jsonl trace.jsonl] [--metrics-port N]

``--metrics-jsonl`` turns on the flight recorder: one structured
``train_step`` record per log boundary (loss, grad norm, step wall,
device-side tokens/s) plus a final metrics snapshot; ``--metrics-port``
serves the same registry as live Prometheus text at ``/metrics``.

The training loss comes from the ``repro.losses`` registry — every entry
lowers onto the CCE (lse, pick[, sum]) primitive, so switching losses never
re-introduces the N×V logit matrix. ``--loss-impl`` names a
``repro.backends`` entry; (loss, backend) compatibility is checked by
capability at resolution time, with errors listing the backends that do
support the requested loss.

Runs on whatever devices are available; for the production mesh this is
driven by the cluster launcher with one process per host (jax.distributed),
the code paths are identical.
"""

import argparse
import dataclasses

import repro.configs as configs
from repro import backends
from repro.configs.base import TrainConfig
from repro.launch.cce_flags import add_cce_args, cce_config_from_args
from repro.launch.obs_flags import add_obs_args, obs_from_args
from repro.losses import LossConfig, list_losses
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--loss-impl", default=None,
                    choices=["auto"] + backends.list_backends(),
                    help="repro.backends entry for the loss head")
    ap.add_argument("--loss", default="nll",
                    help=f"registry loss; one of {list_losses()}")
    ap.add_argument("--loss-kwargs", default="{}",
                    help='JSON hyper-parameters for --loss, e.g. '
                         '\'{"z_weight": 1e-4}\'')
    ap.add_argument("--dtype", default=None)
    add_cce_args(ap)
    add_obs_args(ap)
    args = ap.parse_args()

    cfg = (configs.get_reduced_config(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if args.loss_impl:
        cfg = dataclasses.replace(cfg, loss_impl=args.loss_impl)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    loss_cfg = LossConfig.from_json(args.loss, args.loss_kwargs)
    tcfg = TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 20, 1),
                       microbatch=args.microbatch,
                       loss=loss_cfg.name, loss_kwargs=loss_cfg.kwargs)
    metrics, tracer, obs_finish = obs_from_args(args)
    tr = Trainer(cfg, tcfg, checkpoint_dir=args.ckpt, seq_len=args.seq,
                 global_batch=args.batch,
                 cce_cfg=cce_config_from_args(args),
                 metrics=metrics, tracer=tracer)
    tr.install_signal_handlers()
    tr.run(num_steps=args.steps)
    if args.ckpt:
        tr.save()
    obs_finish()


if __name__ == "__main__":
    main()
