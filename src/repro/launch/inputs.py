"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable argument
stand-ins (no device allocation) for the train step; ``serve_specs`` the
same for the decode step (one new token against a seq_len KV cache).

Conventions for the stub modality frontends (assignment):
  * [audio] seamless: encoder input = precomputed frame embeddings,
    S_enc = seq_len // 4 (≈ 4x temporal compression of a speech encoder);
    decoder operates on seq_len text tokens.
  * [vlm] qwen2-vl: inputs are precomputed patch/text embeddings (B, S, d)
    plus the three M-RoPE position streams.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import data_axes_of
from repro.models import transformer as T
from repro.sharding.specs import _shard_if, cache_specs


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _batch_axes(mesh, batch):
    dp = data_axes_of(mesh)
    return _shard_if(mesh, batch, dp)


def train_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(args_sds: dict, shardings: dict) for train_step's ``batch``."""
    b, s = shape.global_batch, shape.seq_len
    dp = _batch_axes(mesh, b)
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    args, shard = {}, {}

    if cfg.input_mode == "embeds":
        args["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)
        shard["embeds"] = _ns(mesh, dp, None, None)
        if cfg.rope_sections is not None:
            args["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
            shard["positions"] = _ns(mesh, None, dp, None)
    else:
        args["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        shard["tokens"] = _ns(mesh, dp, None)
    args["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    shard["labels"] = _ns(mesh, dp, None)
    if cfg.is_encdec:
        s_enc = max(s // 4, 1)
        args["enc_embeds"] = jax.ShapeDtypeStruct((b, s_enc, cfg.d_model),
                                                  bf16)
        shard["enc_embeds"] = _ns(mesh, dp, None, None)
    return args, shard


def serve_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Stand-ins for serve_step(params, cache, tokens, cache_index [,enc]).

    decode_*: one new token at position seq_len-1 with a seq_len cache.
    """
    b, s = shape.global_batch, shape.seq_len
    dp = _batch_axes(mesh, b)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, b, s))
    c_specs = cache_specs(cfg, cache, mesh, data_axes_of(mesh))
    c_shard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), c_specs,
                           is_leaf=lambda x: isinstance(x, P))

    args = {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache_index": jax.ShapeDtypeStruct((), jnp.int32),
    }
    shard = {
        "cache": c_shard,
        "tokens": _ns(mesh, dp, None),
        "cache_index": NamedSharding(mesh, P()),
    }
    if cfg.is_encdec:
        s_enc = max(s // 4, 1)
        args["enc_out"] = jax.ShapeDtypeStruct(
            (b, s_enc, cfg.d_model), jnp.dtype(cfg.dtype))
        shard["enc_out"] = _ns(mesh, dp, None, None)
    return args, shard


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic-decode families."""
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or all(k in ("swa", "rglru", "rwkv6")
                   for k in cfg.pattern_for(cfg.num_layers)))
        if not sub_quadratic:
            return False, ("skip: pure full-attention arch — a 512k dense KV "
                           "cache is a capacity gate (DESIGN.md §6)")
    return True, ""
