"""Shared ``--metrics-*`` CLI flags -> a wired-up observability stack.

Used by ``launch/serve`` and ``launch/train`` (same idiom as
``launch/cce_flags``):

  --metrics-jsonl PATH   flight-recorder JSONL trace: per-request/step
                         spans + events while running, one final metrics
                         snapshot at shutdown (repro.obs.trace format).
  --metrics-port N       Prometheus scrape endpoint at
                         http://127.0.0.1:N/metrics for the lifetime of
                         the process (N=0 picks a free port and prints it).

``obs_from_args`` returns ``(metrics, tracer, finish)`` — registry/tracer
are ``None`` when no flag was given (subsystems then run their free no-op
path), and ``finish()`` flushes the final snapshot and closes the sink.
"""

from __future__ import annotations

from repro.obs import JsonlSink, Registry, Tracer, start_http_server


def add_obs_args(ap) -> None:
    g = ap.add_argument_group("observability")
    g.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                   help="write metrics snapshots + trace spans to this "
                        "JSONL file (flight recorder)")
    g.add_argument("--metrics-port", type=int, default=None, metavar="N",
                   help="serve Prometheus text exposition at "
                        "http://127.0.0.1:N/metrics (0 = pick a port)")


def obs_from_args(args):
    """(metrics, tracer, finish) from parsed args; (None, None, no-op)
    when observability was not requested."""
    if args.metrics_jsonl is None and args.metrics_port is None:
        return None, None, lambda: None
    registry = Registry()
    sink = JsonlSink(args.metrics_jsonl) if args.metrics_jsonl else None
    tracer = Tracer(sink)
    server = None
    if args.metrics_port is not None:
        server = start_http_server(registry, args.metrics_port)
        print(f"# metrics: http://127.0.0.1:"
              f"{server.server_address[1]}/metrics")

    def finish():
        tracer.snapshot(registry)
        if sink is not None:
            sink.close()
        if server is not None:
            server.shutdown()

    return registry, tracer, finish
