"""Shared CLI surface for the kernel-level :class:`CCEConfig` knobs.

``launch/train.py`` and ``launch/dryrun.py`` both expose the CCE kernel
configuration (vocab sorting, gradient-filter modes, accumulator) that was
previously only reachable by constructing a ``CCEConfig`` in code. Flag
names and value choices are validated against the dataclass fields
themselves, so a knob added to ``CCEConfig`` that is listed here but
renamed/removed fails loudly at CLI-build time instead of silently
drifting.
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.kernels.ops import CCEConfig

# flag -> (dataclass field, argparse kwargs). Value choices mirror the
# semantics documented on CCEConfig itself.
_FLAGS = {
    "--cce-sort-vocab": ("sort_vocab", dict(
        action="store_true", default=None,
        help="permute C by descending average logit before the backward "
             "(paper §4.3 vocabulary sorting)")),
    "--cce-filter-mode-e": ("filter_mode_e", dict(
        choices=["filtered", "full"], default=None,
        help="gradient filtering for the embedding backward "
             "(filtered = paper default, full = no filtering)")),
    "--cce-filter-mode-c": ("filter_mode_c", dict(
        choices=["filtered", "full"], default=None,
        help="gradient filtering for the classifier backward "
             "(full = the paper's CCE-*-FullC pretraining setting)")),
    "--cce-accum": ("accum", dict(
        choices=["f32", "bf16_kahan", "bf16"], default=None,
        help="backward accumulator: f32 (TPU-native default), bf16_kahan "
             "(paper CCE-Kahan parity), bf16 (ablation only)")),
    "--cce-bwd": ("bwd", dict(
        choices=["two_pass", "fused"], default=None,
        help="backward strategy: fused (default; one logit-tile recompute "
             "feeds both dE and dC) or two_pass (classic dE-then-dC "
             "passes). fused falls back to two_pass when --cce-accum is "
             "not f32")),
    "--cce-filter-stats": ("filter_stats", dict(
        choices=["recompute", "fwd_bitmap"], default=None,
        help="gradient-filter statistic source: fwd_bitmap (default; the "
             "forward emits a live-block bitmap so dead blocks skip the "
             "tile recompute) or recompute (paper Alg. 4; statistic from "
             "the recomputed tile). The bitmap auto-disables when nothing "
             "filters (label smoothing / filter modes full)")),
}


def _validate_flags():
    fields = {f.name for f in dataclasses.fields(CCEConfig)}
    for flag, (field, _) in _FLAGS.items():
        if field not in fields:
            raise RuntimeError(
                f"CLI flag {flag} names CCEConfig field {field!r} which "
                f"does not exist; CCEConfig fields: {sorted(fields)}")


def add_cce_args(ap: argparse.ArgumentParser) -> None:
    """Install the ``--cce-*`` flags on ``ap`` (validated vs CCEConfig)."""
    _validate_flags()
    g = ap.add_argument_group("CCE kernel knobs (repro.kernels.ops)")
    for flag, (field, kwargs) in _FLAGS.items():
        g.add_argument(flag, dest=f"cce_{field}", **kwargs)


def cce_config_from_args(args) -> CCEConfig | None:
    """Build a CCEConfig from parsed args; None when no knob was set, so
    call sites keep their default-config path untouched."""
    overrides = {}
    for field, _ in _FLAGS.values():
        v = getattr(args, f"cce_{field}", None)
        if v is not None:
            overrides[field] = v
    return CCEConfig(**overrides) if overrides else None
