"""Roofline analysis: HLO text analyzer + 3-term roofline model."""
from repro.analysis import hlo  # noqa: F401
from repro.analysis.roofline import roofline, model_flops  # noqa: F401
