"""Three-term roofline model from the compiled dry-run artifact.

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs   / (chips * 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes   / (chips * 819e9  B/s HBM)
    collective term = coll_bytes  / (chips * 50e9   B/s per ICI link)

HLO_FLOPs / HLO_bytes / coll_bytes come from the HLO text analyzer
(analysis/hlo.py) with while-loop trip multipliers, evaluated on the
post-SPMD per-device module and multiplied back by chip count for the
global figures. MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) gives the
"useful compute" ratio that exposes remat/dispatch waste.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig

# TPU v5e-class chip constants (assignment).
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6 * params_active * tokens (train includes backward; decode 2*N*D)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else 1)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    # forward-only (prefill counts the full sequence)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
    return 2.0 * n_active * tokens


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    per_device_bytes: float | None = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(per_device: dict, chips: int, cfg: ModelConfig,
             shape: ShapeConfig, memory_stats=None) -> Roofline:
    """per_device: output of analysis.hlo.analyze (per-device numbers)."""
    t_comp = per_device["flops"] / PEAK_FLOPS_BF16
    t_mem = per_device["traffic_bytes"] / HBM_BW
    t_coll = per_device["collective_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = per_device["flops"] * chips
    per_dev_bytes = None
    if memory_stats is not None:
        per_dev_bytes = (memory_stats.argument_size_in_bytes
                         + memory_stats.output_size_in_bytes
                         + memory_stats.temp_size_in_bytes
                         - memory_stats.alias_size_in_bytes)
    return Roofline(
        compute_s=t_comp, memory_s=t_mem, collective_s=t_coll,
        dominant=dominant, model_flops=mf, hlo_flops=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        per_device_bytes=per_dev_bytes)
