"""Pallas contract checker: static extraction + verification of kernel
launch parameters, without executing anything.

Tracing a kernel wrapper with ``jax.make_jaxpr`` over abstract arguments
leaves the ``pallas_call`` primitive equations in the jaxpr; their params
carry everything the contracts talk about:

  * ``grid_mapping`` — grid, per-operand block shapes + full array shapes
    (``block_mappings``), scratch operand count,
  * the kernel body jaxpr — scratch avals (trailing VMEM MemRef invars)
    and every intermediate the kernel allocates (e.g. the logit tile),
  * ``input_output_aliases`` and ``compiler_params`` (dimension semantics).

From these we verify, for every kernel entry point in ``repro.kernels``:

  1. **VMEM budget** — the structural working set (one copy of every
     input/output block + scratch + the largest kernel intermediate) fits
     in ``kernels._util.VMEM_BUDGET``. (The budget is set at ~12 MB of the
     16 MB/core precisely so the pipeline's double-buffering headroom
     lives in the remaining ~4 MB; the structural set is the single-copy
     footprint the formulas model.)
  2. **VMEM claim** — ``vmem_working_set`` / ``decode_vmem_working_set``
     (what ``choose_blocks`` budgets against) does not *understate* the
     structural working set: structural <= claimed + small slack.
  3. **f32 accumulators** — no 16-bit float scratch operand, ever; all
     accumulation happens in f32 (or int32 bookkeeping).
  4. **alias discipline** — every ``input_output_aliases`` entry pairs an
     input and an output of identical shape+dtype (a donatable seed).
  5. **tile discipline** — every block shape divides its (padded) array
     shape, and respects TPU tiling: last dim in {1, full} or a multiple
     of 128, second-to-last in {1, full} or a multiple of 8.

:func:`sweep_cce_knobs` additionally proves — by pure arithmetic over
``kernel_plan``/``choose_decode_blocks``, no tracing — that every
``CCEConfig`` knob combination at every paper geometry in ``repro.configs``
resolves to blocks whose claimed working set fits the budget.
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.analysis.checks.common import CheckError, Finding
from repro.kernels._util import VMEM_BUDGET

#: Slack allowed on the claim check (index columns, padding, bookkeeping
#: buffers the closed-form formulas round away).
CLAIM_SLACK_BYTES = 16 * 1024


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    """One pallas operand: its block window and the full array behind it."""

    origin: str                 # e.g. "e_ref", "outputs[0]"
    block_shape: tuple
    array_shape: tuple
    dtype: str

    @property
    def block_bytes(self) -> int:
        import numpy as np
        elems = 1
        for b in self.block_shape:
            elems *= int(b)
        return elems * np.dtype(self.dtype).itemsize


@dataclasses.dataclass
class PallasCallInfo:
    """Statically extracted launch parameters of one ``pallas_call``."""

    name: str
    grid: tuple
    in_blocks: list          # [BlockInfo]
    out_blocks: list         # [BlockInfo]
    scratch_avals: list      # [(shape, dtype)]
    aliases: tuple           # ((in_idx, out_idx), ...)
    in_avals: list           # [(shape, dtype)] pallas_call inputs
    out_avals: list          # [(shape, dtype)] pallas_call outputs
    dimension_semantics: tuple
    num_index_operands: int
    max_intermediate_bytes: int
    max_intermediate: str    # "dtype[shape]" of the largest kernel temp

    def structural_vmem(self) -> int:
        """Single-copy working set: every block window + scratch + the
        largest kernel-body intermediate (the recomputed logit tile)."""
        import numpy as np
        total = sum(b.block_bytes for b in self.in_blocks)
        total += sum(b.block_bytes for b in self.out_blocks)
        for shape, dtype in self.scratch_avals:
            elems = 1
            for s in shape:
                elems *= int(s)
            total += elems * np.dtype(dtype).itemsize
        return total + self.max_intermediate_bytes


def _walk_pallas_eqns(jaxpr, found):
    import jax.core as jcore
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            found.append(eqn)
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for sub in vals:
                if isinstance(sub, jcore.ClosedJaxpr):
                    _walk_pallas_eqns(sub.jaxpr, found)
                elif isinstance(sub, jcore.Jaxpr):
                    _walk_pallas_eqns(sub, found)


def _aval_sig(aval):
    return (tuple(int(s) for s in aval.shape), str(aval.dtype))


def _eqn_to_info(eqn) -> PallasCallInfo:
    import numpy as np
    gm = eqn.params["grid_mapping"]
    name = eqn.params.get("name_and_src_info")
    name = getattr(name, "name", str(name))
    n_in, n_out = gm.num_inputs, gm.num_outputs
    blocks = []
    for bm in gm.block_mappings:
        asd = bm.array_shape_dtype
        blocks.append(BlockInfo(
            origin=str(getattr(bm, "origin", "")),
            block_shape=tuple(int(b) for b in bm.block_shape),
            array_shape=tuple(int(s) for s in asd.shape),
            dtype=str(asd.dtype)))
    in_blocks, out_blocks = blocks[:n_in], blocks[n_in:n_in + n_out]

    kjaxpr = eqn.params["jaxpr"]
    n_scratch = gm.num_scratch_operands
    scratch = []
    if n_scratch:
        for invar in kjaxpr.invars[-n_scratch:]:
            inner = getattr(invar.aval, "inner_aval", invar.aval)
            scratch.append((tuple(int(s) for s in inner.shape),
                            str(inner.dtype)))

    # Largest intermediate the kernel body computes (e.g. the logit tile).
    max_bytes, max_desc = 0, ""
    stack = [kjaxpr]
    while stack:
        jx = stack.pop()
        for keqn in jx.eqns:
            for var in keqn.outvars:
                aval = getattr(var, "aval", None)
                shape = getattr(aval, "shape", None)
                if shape is None or not hasattr(aval, "dtype"):
                    continue
                elems = 1
                for s in shape:
                    elems *= int(s)
                nbytes = elems * np.dtype(aval.dtype).itemsize
                if nbytes > max_bytes:
                    max_bytes = nbytes
                    max_desc = f"{aval.dtype}{list(shape)}"
            for val in keqn.params.values():
                vals = val if isinstance(val, (tuple, list)) else (val,)
                for sub in vals:
                    if hasattr(sub, "eqns"):
                        stack.append(sub)
                    elif hasattr(sub, "jaxpr"):
                        stack.append(sub.jaxpr)

    cparams = eqn.params.get("compiler_params") or {}
    mosaic = cparams.get("mosaic", cparams) if isinstance(cparams, dict) \
        else cparams
    dimsem = tuple((mosaic or {}).get("dimension_semantics", ()) or ()) \
        if isinstance(mosaic, dict) else ()

    n_index = gm.num_index_operands
    in_avals = [_aval_sig(v.aval) for v in eqn.invars[n_index:]]
    out_avals = [_aval_sig(v.aval) for v in eqn.outvars]
    return PallasCallInfo(
        name=name, grid=tuple(int(g) for g in gm.grid),
        in_blocks=in_blocks, out_blocks=out_blocks,
        scratch_avals=scratch,
        aliases=tuple((int(i), int(o))
                      for i, o in eqn.params["input_output_aliases"]),
        in_avals=in_avals, out_avals=out_avals,
        dimension_semantics=dimsem, num_index_operands=n_index,
        max_intermediate_bytes=max_bytes, max_intermediate=max_desc)


def extract_pallas_calls(fn, *example_args, **kwargs) -> list:
    """Trace ``fn`` over abstract args and return a :class:`PallasCallInfo`
    for every ``pallas_call`` in the jaxpr (recursing through scan / cond /
    pjit bodies). Nothing is executed."""
    import jax
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*example_args)
    found: list = []
    _walk_pallas_eqns(jaxpr.jaxpr, found)
    return [_eqn_to_info(eqn) for eqn in found]


# ---------------------------------------------------------------------------
# Per-call contract checks
# ---------------------------------------------------------------------------

_16BIT_FLOATS = ("bfloat16", "float16")


def check_contracts(info: PallasCallInfo, *, claimed_bytes: int | None = None,
                    budget: int = VMEM_BUDGET,
                    subject: str | None = None) -> list:
    """All per-call contract findings for one extracted ``pallas_call``."""
    subject = subject or info.name
    findings = []
    structural = info.structural_vmem()

    findings.append(Finding(
        family="pallas", invariant="vmem_budget", subject=subject,
        ok=structural <= budget,
        detail=(f"structural working set {structural} B "
                f"(blocks + scratch + max intermediate "
                f"{info.max_intermediate or 'none'}) vs budget {budget} B"),
        data={"structural_bytes": structural, "budget_bytes": budget,
              "grid": info.grid,
              "max_intermediate": info.max_intermediate}))

    if claimed_bytes is not None:
        ok = structural <= claimed_bytes + CLAIM_SLACK_BYTES
        findings.append(Finding(
            family="pallas", invariant="vmem_claim", subject=subject,
            ok=ok and claimed_bytes <= budget,
            detail=(f"claimed {claimed_bytes} B vs structural {structural} B"
                    f" (slack {CLAIM_SLACK_BYTES} B); claim must not "
                    "understate and must fit the budget"),
            data={"claimed_bytes": claimed_bytes,
                  "structural_bytes": structural,
                  "budget_bytes": budget}))

    bad_scratch = [f"{dt}{list(sh)}" for sh, dt in info.scratch_avals
                   if dt in _16BIT_FLOATS]
    findings.append(Finding(
        family="pallas", invariant="accum_f32", subject=subject,
        ok=not bad_scratch,
        detail=("scratch accumulators: "
                + (", ".join(f"{dt}{list(sh)}"
                             for sh, dt in info.scratch_avals) or "none")
                + (f"; 16-bit float scratch forbidden: {bad_scratch}"
                   if bad_scratch else " — all f32/int32")),
        data={"scratch": [f"{dt}{list(sh)}"
                          for sh, dt in info.scratch_avals],
              "bad": bad_scratch}))

    alias_problems = []
    for in_idx, out_idx in info.aliases:
        if in_idx >= len(info.in_avals) or out_idx >= len(info.out_avals):
            alias_problems.append(
                f"alias ({in_idx}->{out_idx}) out of range")
            continue
        ia, oa = info.in_avals[in_idx], info.out_avals[out_idx]
        if ia != oa:
            alias_problems.append(
                f"alias ({in_idx}->{out_idx}): input {ia[1]}{list(ia[0])}"
                f" != output {oa[1]}{list(oa[0])}")
    findings.append(Finding(
        family="pallas", invariant="alias_shape", subject=subject,
        ok=not alias_problems,
        detail=(f"{len(info.aliases)} input_output_aliases"
                + ("" if not alias_problems
                   else "; " + "; ".join(alias_problems))),
        data={"aliases": list(info.aliases), "problems": alias_problems}))

    tile_problems = []
    for blk in info.in_blocks + info.out_blocks:
        bs, ash = blk.block_shape, blk.array_shape
        for axis, (b, a) in enumerate(zip(bs, ash)):
            if b <= 0:
                tile_problems.append(f"{blk.origin}: axis {axis} block {b}")
            elif a % b and b < a:
                tile_problems.append(
                    f"{blk.origin}: block {list(bs)} axis {axis} ({b}) "
                    f"does not divide array {list(ash)}")
        if len(bs) >= 1:
            last, alast = bs[-1], ash[-1]
            if last not in (1, alast) and last % 128:
                tile_problems.append(
                    f"{blk.origin}: last block dim {last} not 1/full/128k")
        if len(bs) >= 2:
            sec, asec = bs[-2], ash[-2]
            if sec not in (1, asec) and sec % 8:
                tile_problems.append(
                    f"{blk.origin}: 2nd-last block dim {sec} not 1/full/8k")
    findings.append(Finding(
        family="pallas", invariant="tile_discipline", subject=subject,
        ok=not tile_problems,
        detail=("block shapes divide padded dims and respect (8,128) tiling"
                if not tile_problems else "; ".join(tile_problems)),
        data={"problems": tile_problems,
              "blocks": [f"{b.origin}:{list(b.block_shape)}"
                         f"/{list(b.array_shape)}"
                         for b in info.in_blocks + info.out_blocks]}))
    return findings


def assert_kernel_contracts(fn, *example_args, claimed_bytes=None,
                            subject=None, **kwargs) -> list:
    """Extract + check; raises :class:`CheckError` on any violation."""
    infos = extract_pallas_calls(fn, *example_args, **kwargs)
    if not infos:
        raise CheckError(f"no pallas_call found tracing {fn}")
    findings = []
    for info in infos:
        findings += check_contracts(info, claimed_bytes=claimed_bytes,
                                    subject=subject or info.name)
    bad = [f for f in findings if not f.ok]
    if bad:
        raise CheckError(
            "pallas contract violations: "
            + "; ".join(f"[{f.invariant}] {f.subject}: {f.detail}"
                        for f in bad), bad)
    return findings


# ---------------------------------------------------------------------------
# Entry-point sweep: every kernel in repro.kernels, traced at a small
# geometry with the real (non-interpret) launch parameters.
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, getattr(jnp, dtype))


def kernel_entry_points() -> list:
    """``[(subject, thunk)]``; each thunk returns
    ``(fn, example_args, static_kwargs, claimed_bytes)``."""
    import jax.numpy as jnp

    from repro.kernels import cce_bwd, cce_fwd, decode_sample
    from repro.kernels import indexed_matmul, wkv
    from repro.kernels.ops import vmem_working_set

    n, v, d = 256, 2048, 64
    bn, bv = 128, 256
    E = _sds((n, d), "float32")
    C = _sds((v, d), "float32")
    x = _sds((n,), "int32")
    col = _sds((n,), "float32")
    nn, nv = n // bn, v // bv
    bitmap = _sds((nn, nv), "int32")
    ws = lambda **kw: vmem_working_set(bn, bv, d, 4, **kw)

    entries = [
        ("cce_fwd", lambda: (
            cce_fwd.cce_forward_pallas, (E, C, x), {}, ws())),
        ("cce_fwd+sum", lambda: (
            cce_fwd.cce_forward_pallas, (E, C, x),
            dict(with_sum=True), ws(with_sum=True))),
        ("cce_fwd+bitmap", lambda: (
            cce_fwd.cce_forward_pallas, (E, C, x),
            dict(emit_bitmap=True, filter_eps=2.0 ** -12),
            ws(emit_bitmap=True, vocab=v))),
        ("cce_bwd_dE", lambda: (
            cce_bwd.cce_backward_dE_pallas, (E, C, x, col, col, col),
            {}, ws())),
        ("cce_bwd_dE+kahan", lambda: (
            cce_bwd.cce_backward_dE_pallas, (E, C, x, col, col, col),
            dict(accum="bf16_kahan"), ws(kahan=True))),
        ("cce_bwd_dC", lambda: (
            cce_bwd.cce_backward_dC_pallas, (E, C, x, col, col, col),
            {}, ws())),
        ("cce_bwd_fused", lambda: (
            cce_bwd.cce_backward_fused_pallas, (E, C, x, col, col, col),
            {}, ws(accum_rows=2))),
        ("cce_bwd_fused+bitmap", lambda: (
            cce_bwd.cce_backward_fused_pallas,
            (E, C, x, col, col, col, bitmap),
            {}, ws(accum_rows=2, emit_bitmap=True, vocab=v))),
        ("indexed_matmul", lambda: (
            indexed_matmul.indexed_matmul_pallas,
            (_sds((64, d), "float32"), _sds((512, d), "float32"),
             _sds((64,), "int32")), {}, None)),
        ("wkv_fwd", lambda: (
            wkv.wkv_forward_pallas,
            (_sds((2, 2, 256, 64), "float32"),) * 4
            + (_sds((2, 64), "float32"), _sds((2, 2, 64, 64), "float32")),
            dict(chunk_len=128), None)),
    ]

    bb, dbv = 8, 512
    dws = decode_sample.decode_vmem_working_set
    h = _sds((16, d), "float32")
    Cd = _sds((2048, d), "float32")
    keys = _sds((16, 2), "uint32")
    tau = _sds((16,), "float32")
    entries += [
        ("decode_sample(filtered)", lambda: (
            decode_sample.decode_sample_pallas,
            (h, Cd, keys, tau, tau, tau),
            dict(vocab=2000, with_filter=True, block_b=bb, block_v=dbv),
            dws(bb, dbv, d, 4, with_filter=True,
                n_buckets=decode_sample.DEFAULT_BUCKETS))),
        ("decode_sample(sweep)", lambda: (
            decode_sample.decode_sample_pallas,
            (h, Cd, keys, tau, tau, tau),
            dict(vocab=2000, with_filter=False, block_b=bb, block_v=dbv),
            dws(bb, dbv, d, 4, with_filter=False))),
    ]
    return entries


def check_kernel_entry_points() -> list:
    """Trace + verify every kernel entry point; returns all findings."""
    findings = []
    for subject, thunk in kernel_entry_points():
        fn, args, kwargs, claimed = thunk()
        if subject == "cce_bwd_fused+bitmap":
            # bitmap rides as the last positional so it traces with the
            # other args, but the kernel wrapper takes it as a keyword.
            *args, bmp = args
            kwargs = dict(kwargs, bitmap=bmp)
        try:
            infos = extract_pallas_calls(fn, *args, **kwargs)
        except Exception as exc:  # tracing itself failed: report, continue
            findings.append(Finding(
                family="pallas", invariant="traceable", subject=subject,
                ok=False, detail=f"tracing failed: {exc!r}"))
            continue
        if not infos:
            findings.append(Finding(
                family="pallas", invariant="traceable", subject=subject,
                ok=False, detail="no pallas_call in trace"))
            continue
        for info in infos:
            findings += check_contracts(
                info, claimed_bytes=claimed, subject=subject)
    return findings


# ---------------------------------------------------------------------------
# Knob sweep: all CCEConfig combinations x all paper geometries, by pure
# arithmetic on the block chooser (nothing traced).
# ---------------------------------------------------------------------------

def sweep_cce_knobs(n_tokens: int = 8192, itemsizes=(2, 4)) -> list:
    """For every paper geometry in ``repro.configs`` and every CCEConfig
    knob combination, the resolved plan's claimed working set must fit the
    budget and the blocks must be (8,128)-tile aligned."""
    from repro import configs
    from repro.kernels.decode_sample import (choose_decode_blocks,
                                             decode_vmem_working_set)
    from repro.kernels.ops import CCEConfig, kernel_plan

    findings = []
    combos = list(itertools.product(
        ("fused", "two_pass"), ("f32", "bf16", "bf16_kahan"),
        ("filtered", "full"), ("filtered", "full"),
        ("recompute", "fwd_bitmap"), (False, True)))
    for arch in configs.ASSIGNED:
        cfg = configs.get_config(arch)
        v, d = cfg.padded_vocab_size, cfg.d_model
        problems = []
        n_checked = 0
        for itemsize in itemsizes:
            for bwd, accum, fme, fmc, stats, want_sum in combos:
                ccfg = CCEConfig(filter_mode_e=fme, filter_mode_c=fmc,
                                 accum=accum, bwd=bwd, filter_stats=stats)
                plan = kernel_plan(n_tokens, v, d, itemsize, ccfg,
                                   want_sum=want_sum)
                n_checked += 1
                tag = (f"bwd={bwd},accum={accum},fm=({fme},{fmc}),"
                       f"stats={stats},sum={want_sum},item={itemsize}")
                if plan["vmem_working_set_bytes"] > plan["vmem_budget_bytes"]:
                    problems.append(
                        f"{tag}: ws {plan['vmem_working_set_bytes']} > "
                        f"budget {plan['vmem_budget_bytes']}")
                if plan["block_n"] % 8:
                    problems.append(
                        f"{tag}: block_n {plan['block_n']} not 8-aligned")
                if plan["block_v"] % 128:
                    problems.append(
                        f"{tag}: block_v {plan['block_v']} not 128-aligned")
            bb, bv = choose_decode_blocks(512, v, d, itemsize)
            for wf in (False, True):
                n_checked += 1
                dws = decode_vmem_working_set(bb, bv, d, itemsize,
                                              with_filter=wf)
                if dws > VMEM_BUDGET:
                    problems.append(
                        f"decode(item={itemsize},filter={wf}): ws {dws} > "
                        f"budget {VMEM_BUDGET}")
            if bb % 8 or bv % 128:
                problems.append(
                    f"decode blocks ({bb},{bv}) not (8,128)-aligned")
        findings.append(Finding(
            family="pallas", invariant="knob_sweep", subject=arch,
            ok=not problems,
            detail=(f"{n_checked} knob combinations at V={v} D={d} "
                    f"N={n_tokens}: "
                    + ("all plans fit the VMEM budget, tile-aligned"
                       if not problems else "; ".join(problems[:8]))),
            data={"v": v, "d": d, "n": n_tokens, "checked": n_checked,
                  "problems": problems}))
    return findings
