"""repro.analysis.checks — static invariant verifier for the CCE contracts.

Proves the repo's load-bearing claims without executing kernels:

  * :mod:`memclass` — no O(N·V)-class intermediate in any compiled loss /
    scoring / decode program (``assert_memory_class``, ``class_rank``);
  * :mod:`pallas` — kernel launch contracts (VMEM working set vs budget &
    formula claims, f32 accumulators, alias discipline, tile alignment)
    extracted from traced jaxprs (``extract_pallas_calls``);
  * :mod:`syncaudit` — the serving engine's "one device_get per step"
    invariant and jit retrace hygiene, from the AST + jit introspection;
  * :mod:`lint` — repo conventions (pallas_call only under
    ``kernels/``, no host syncs in ``serve/`` step paths, CLI flags match
    their dataclass fields).

CLI: ``python -m repro.analysis.checks [--json out.json]`` — runs every
family, prints per-invariant findings, exits non-zero on violation.
"""

from repro.analysis.checks.common import CheckError, Finding, Report  # noqa: F401
from repro.analysis.checks.memclass import (  # noqa: F401
    CCE_CLASS,
    CHUNKED_CLASS,
    DENSE_CLASS,
    assert_memory_class,
    census_budget,
    check_memory_class,
    class_rank,
    classify_elems,
    classify_hlo,
    classify_jaxpr,
    jaxpr_shape_census,
)
from repro.analysis.checks.pallas import (  # noqa: F401
    PallasCallInfo,
    assert_kernel_contracts,
    check_contracts,
    check_kernel_entry_points,
    extract_pallas_calls,
    sweep_cce_knobs,
)
