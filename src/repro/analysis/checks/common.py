"""Shared result types for the static invariant verifier.

Every analyzer family (memclass / pallas / syncaudit / lint) reports
:class:`Finding` records collected into a :class:`Report`. A finding is a
single invariant evaluation — passed or failed — so the CLI can print the
full catalogue of what was *proved*, not only what broke.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass
class Finding:
    """One invariant evaluation.

    family:    analyzer family ("memclass" | "pallas" | "sync" | "lint")
    invariant: short machine-readable invariant id (e.g. "memory_class",
               "vmem_budget", "alias_shape", "one_device_get")
    subject:   what was checked (backend name, kernel entry point, file)
    ok:        True iff the invariant holds
    detail:    human-readable evidence (observed vs expected)
    data:      structured evidence for the JSON report
    """

    family: str
    invariant: str
    subject: str
    ok: bool
    detail: str = ""
    data: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "family": self.family,
            "invariant": self.invariant,
            "subject": self.subject,
            "ok": self.ok,
            "detail": self.detail,
            "data": _jsonable(self.data),
        }


def _jsonable(obj: Any):
    """Best-effort conversion to JSON-serializable structures."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


@dataclasses.dataclass
class Report:
    """Collected findings with pass/fail accounting."""

    findings: list = dataclasses.field(default_factory=list)

    def add(self, finding: Finding) -> Finding:
        self.findings.append(finding)
        return finding

    def extend(self, findings) -> None:
        for f in findings:
            self.add(f)

    @property
    def failures(self) -> list:
        return [f for f in self.findings if not f.ok]

    @property
    def ok(self) -> bool:
        return not self.failures

    def as_dict(self) -> dict:
        return {
            "ok": self.ok,
            "checked": len(self.findings),
            "failed": len(self.failures),
            "findings": [f.as_dict() for f in self.findings],
        }

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.as_dict(), indent=2, **kwargs)


class CheckError(AssertionError):
    """Raised by the assert_* helpers; carries the failing findings."""

    def __init__(self, message: str, findings=()):
        super().__init__(message)
        self.findings = list(findings)
