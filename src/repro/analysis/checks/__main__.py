"""CLI: ``python -m repro.analysis.checks [--json out.json] [--only FAM]``.

Runs every analyzer family and prints one line per invariant finding;
exits non-zero if any invariant fails. ``--json`` additionally writes the
full structured report (CI uploads it as an artifact).

Families:
  memclass  every backend / loss / scoring path / fused decode jit stays
            out of the O(N·V) memory class (AOT lowering + HLO census)
  pallas    kernel launch contracts: VMEM working set vs budget and the
            vmem_working_set formula claims, f32 accumulators, alias and
            tile discipline, plus the CCEConfig knob x geometry sweep
  sync      the engine's one-device_get-per-step invariant and jit
            retrace hygiene
  lint      repo conventions (pallas_call location, host-sync location,
            CLI flags vs dataclass fields)
"""

from __future__ import annotations

import argparse
import sys
import time


def _families():
    from repro.analysis.checks import lint, pallas, prove, syncaudit
    return {
        "memclass": prove.prove_all,
        "pallas": lambda: (pallas.check_kernel_entry_points()
                           + pallas.sweep_cce_knobs()),
        "sync": syncaudit.audit_all,
        "lint": lint.lint_all,
    }


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.checks",
        description="static invariant verifier for the CCE contracts")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write the structured findings report here")
    parser.add_argument("--only", action="append", default=None,
                        choices=sorted(_families()),
                        help="run only this analyzer family (repeatable)")
    parser.add_argument("--quiet", action="store_true",
                        help="print failures only")
    args = parser.parse_args(argv)

    from repro.analysis.checks.common import Report

    report = Report()
    families = _families()
    selected = args.only or sorted(families)
    for fam in selected:
        t0 = time.time()
        findings = families[fam]()
        report.extend(findings)
        n_bad = sum(1 for f in findings if not f.ok)
        print(f"== {fam}: {len(findings)} invariants checked, "
              f"{n_bad} failed ({time.time() - t0:.1f}s)")
        for f in findings:
            if args.quiet and f.ok:
                continue
            mark = "ok  " if f.ok else "FAIL"
            print(f"  {mark} [{f.invariant}] {f.subject}: {f.detail}")

    if args.json:
        with open(args.json, "w") as fh:
            fh.write(report.to_json())
        print(f"report -> {args.json}")

    print(f"{'PASS' if report.ok else 'FAIL'}: "
          f"{len(report.findings)} invariants, "
          f"{len(report.failures)} violations")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
