"""Memory-class prover: "no O(N·V)-class intermediate" as a static check.

The paper's central contract is a *memory class*: CCE computes the loss (and
now logit-free decode) in O(N·D + V·D) memory — no buffer proportional to
N·V (tokens × vocabulary) may exist anywhere in the compiled program. This
module turns the repo's scattered hand-rolled census assertions into one
symbolic classifier:

  * bind the problem dimensions (N tokens, V vocab, D model width — decode
    binds N := B, the batch) from the abstract arguments,
  * walk the jaxpr (every equation's output avals, recursing into
    sub-jaxprs) and/or the optimized HLO (``analysis.hlo.array_shape_census``),
  * classify the largest intermediate against the dimension products:

        elems >= N·V                  -> "O(N·V)"       (dense class)
        budget < elems < N·V          -> "O(N/K·V)"     (chunked class)
        elems <= budget               -> "O(N·D + V·D)" (CCE class)

    where ``budget = 4 * max(N·D, V·D)`` — four activation/parameter-sized
    buffers of slack, the same convention the census tests always used.

The check is *discriminating* only when ``budget < N·V``; geometries that
don't satisfy this are rejected rather than silently passing.

:func:`assert_memory_class` is the single helper reused by tests,
benchmarks (``loss_zoo_memory``), the serve CLI's ``--check-memory-class``
and the ``repro.analysis.checks`` CLI. ``class_rank`` is the single source
of truth for ordering memory classes (``benchmarks/perf_gate`` imports it).
"""

from __future__ import annotations

import functools

from repro.analysis import hlo as hlo_an
from repro.analysis.checks.common import CheckError, Finding

CCE_CLASS = "O(N·D + V·D)"
CHUNKED_CLASS = "O(N/K·V)"
DENSE_CLASS = "O(N·V)"

#: Rank order: lower is strictly better (smaller asymptotic footprint).
#: Unknown classes rank worst so a typo'd class never passes a gate.
_CLASS_RANK = {CCE_CLASS: 0, CHUNKED_CLASS: 1, DENSE_CLASS: 2}


def class_rank(cls: str | None) -> int:
    """Order memory classes; unknown strings rank below everything."""
    return _CLASS_RANK.get(cls, len(_CLASS_RANK))


def census_budget(n: int, v: int, d: int) -> int:
    """Largest buffer (in elements) the CCE class may own: four
    activation/parameter-sized arrays of slack, never a function of N·V."""
    return 4 * max(n * d, v * d)


def is_discriminating(n: int, v: int, d: int) -> bool:
    """True iff the budget can actually separate CCE from dense at this
    geometry (budget < N·V)."""
    return census_budget(n, v, d) < n * v


def classify_elems(elems: float, *, n: int, v: int, d: int) -> str:
    """Classify a single buffer size (in elements) against the dims."""
    if elems >= n * v:
        return DENSE_CLASS
    if elems > census_budget(n, v, d):
        return CHUNKED_CLASS
    return CCE_CLASS


# ---------------------------------------------------------------------------
# jaxpr walking pass
# ---------------------------------------------------------------------------

def _iter_sub_jaxprs(params: dict):
    import jax.core as jcore
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for sub in vals:
            if isinstance(sub, jcore.ClosedJaxpr):
                yield sub.jaxpr
            elif isinstance(sub, jcore.Jaxpr):
                yield sub


def jaxpr_shape_census(jaxpr, top: int = 8) -> list:
    """Largest distinct intermediate avals in a (Closed)Jaxpr:
    ``[(elems, "dtype[dims]")]`` sorted descending.

    Walks every equation's *output* avals, recursing into sub-jaxprs
    (scan/while/cond/pjit/pallas_call bodies), so a dense logit matrix
    hidden inside a scanned layer still shows up. Inputs/consts are not
    counted — they are the caller's arrays, not intermediates."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    seen: dict[str, int] = {}

    def walk(jx):
        for eqn in jx.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                shape = getattr(aval, "shape", None)
                if shape is None:
                    continue
                elems = 1
                for dim in shape:
                    elems *= int(dim)
                key = f"{getattr(aval, 'dtype', '?')}{list(shape)}"
                seen[key] = max(seen.get(key, 0), elems)
            for sub in _iter_sub_jaxprs(eqn.params):
                walk(sub)

    walk(inner)
    census = sorted(((e, k) for k, e in seen.items()), reverse=True)
    return census[:top]


# ---------------------------------------------------------------------------
# classification over HLO text / jaxprs / callables
# ---------------------------------------------------------------------------

def _as_hlo_text(target, *example_args, **lower_kwargs) -> str:
    """Accept HLO text, a Lowered/Compiled stage, or a callable (lowered &
    compiled AOT against ``example_args`` ShapeDtypeStructs)."""
    import jax
    if isinstance(target, str):
        return target
    as_text = getattr(target, "as_text", None)
    if as_text is not None and not example_args:
        compile_ = getattr(target, "compile", None)
        if compile_ is not None:  # Lowered: compile for the optimized module
            target = compile_()
        return target.as_text()
    if callable(target):
        fn = target
        if not hasattr(fn, "lower"):
            fn = jax.jit(fn)
        return fn.lower(*example_args, **lower_kwargs).compile().as_text()
    raise TypeError(
        f"cannot extract HLO from {type(target).__name__}; pass HLO text, "
        "a Lowered/Compiled stage, or a callable with example args")


def classify_hlo(hlo_text: str, *, n: int, v: int, d: int) -> str:
    """Memory class of an optimized HLO module at the given dims."""
    census = hlo_an.array_shape_census(hlo_text, top=1)
    largest = census[0][0] if census else 0
    return classify_elems(largest, n=n, v=v, d=d)


def classify_jaxpr(jaxpr, *, n: int, v: int, d: int) -> str:
    """Memory class of a traced jaxpr at the given dims."""
    census = jaxpr_shape_census(jaxpr, top=1)
    largest = census[0][0] if census else 0
    return classify_elems(largest, n=n, v=v, d=d)


def check_memory_class(target, *example_args, n: int, v: int, d: int,
                       max_class: str = CCE_CLASS, what: str = "",
                       **lower_kwargs) -> Finding:
    """Evaluate the memory-class invariant; returns a :class:`Finding`.

    ``target`` may be optimized-HLO text, a ``jax`` Lowered/Compiled stage,
    or a callable (jitted on demand and AOT-lowered against
    ``example_args``). The observed class must rank <= ``max_class``.
    Raises ``ValueError`` if the geometry cannot discriminate."""
    if not is_discriminating(n, v, d):
        raise ValueError(
            f"geometry N={n} V={v} D={d} is not discriminating: census "
            f"budget {census_budget(n, v, d)} >= N*V {n * v}; grow N or V "
            "(the check would pass vacuously)")
    text = _as_hlo_text(target, *example_args, **lower_kwargs)
    census = hlo_an.array_shape_census(text, top=4)
    largest = census[0][0] if census else 0
    observed = classify_elems(largest, n=n, v=v, d=d)
    ok = class_rank(observed) <= class_rank(max_class)
    subject = what or getattr(target, "__name__", type(target).__name__)
    return Finding(
        family="memclass", invariant="memory_class", subject=subject,
        ok=ok,
        detail=(f"observed {observed} (largest buffer {largest} elems, "
                f"budget {census_budget(n, v, d)}, N*V {n * v}); "
                f"required <= {max_class}"),
        data={"observed": observed, "max_class": max_class,
              "largest_elems": largest, "census": census,
              "n": n, "v": v, "d": d,
              "budget_elems": census_budget(n, v, d)})


def assert_memory_class(target=None, *example_args, n: int = 0, v: int = 0,
                        d: int = 0, max_class: str = CCE_CLASS,
                        what: str = "", **lower_kwargs):
    """Assert the memory-class invariant, or build a decorator that does.

    Direct form (tests, benchmarks, CLI gates)::

        assert_memory_class(hlo_text, n=n, v=v, d=d)               # CCE
        assert_memory_class(text, n=n, v=v, d=d,
                            max_class="O(N·V)")                    # bound
        assert_memory_class(fn, E_sds, C_sds, x_sds, n=n, v=v, d=d)

    Decorator form (``target=None``): wraps a function so every call is
    first AOT-lowered against the concrete arguments' avals and checked,
    then executed. One check per distinct input signature::

        @assert_memory_class(n=4096, v=65536, d=512)
        def loss(E, C, x): ...

    Raises :class:`CheckError` (an ``AssertionError``) on violation.
    """
    if target is None:
        def deco(fn):
            import jax
            jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
            checked: set = set()

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                import jax
                key = tuple(
                    (getattr(a, "shape", None), str(getattr(a, "dtype", "")))
                    for a in jax.tree_util.tree_leaves((args, kwargs)))
                if key not in checked:
                    sds = jax.tree_util.tree_map(
                        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
                        if hasattr(a, "shape") else a, (args, kwargs))
                    finding = check_memory_class(
                        jfn, *sds[0], n=n, v=v, d=d, max_class=max_class,
                        what=what or fn.__name__, **sds[1])
                    if not finding.ok:
                        raise CheckError(finding.detail, [finding])
                    checked.add(key)
                return fn(*args, **kwargs)

            return wrapper
        return deco

    finding = check_memory_class(
        target, *example_args, n=n, v=v, d=d, max_class=max_class,
        what=what, **lower_kwargs)
    if not finding.ok:
        raise CheckError(
            f"memory-class violation in {finding.subject}: {finding.detail}",
            [finding])
    return finding
