"""Convention lint: small AST rules that keep the repo's layering honest.

  * ``pallas_call`` (and the pallas import surface) lives only under
    ``src/repro/kernels/`` — everything else goes through the wrapper
    entry points, so the contract checker's kernel inventory stays
    complete by construction.
  * No host syncs (``device_get`` / ``block_until_ready``) outside
    ``serve/engine.py``'s ``_sync`` in the serving package (the counting
    variant of this rule lives in :mod:`syncaudit`; the lint is the
    location rule applied file-by-file).
  * Every ``--cce-*`` CLI flag maps onto a real ``CCEConfig`` dataclass
    field with choices that the dataclass validator accepts — a renamed
    knob fails the lint instead of silently drifting.
"""

from __future__ import annotations

import ast
import dataclasses
import os

from repro.analysis.checks.common import Finding

#: path prefixes (relative to src/repro) allowed to call pallas_call
PALLAS_ALLOWED = ("kernels" + os.sep, "kernels/")


def _repo_src() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", ".."))  # .../src/repro


def _iter_sources(root):
    for dirpath, _dirnames, filenames in os.walk(root):
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                path = os.path.join(dirpath, fname)
                yield os.path.relpath(path, root), path


def find_pallas_calls(source: str, filename: str = "<string>") -> list:
    """Line numbers of ``pallas_call`` call sites / references."""
    tree = ast.parse(source, filename=filename)
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
            hits.append(node.lineno)
        elif isinstance(node, ast.Name) and node.id == "pallas_call":
            hits.append(node.lineno)
    return hits


def lint_pallas_location(src_root: str | None = None) -> list:
    """``pallas_call`` only under ``src/repro/kernels/``."""
    src_root = src_root or _repo_src()
    misplaced = []
    kernel_sites = 0
    for rel, path in _iter_sources(src_root):
        with open(path) as fh:
            hits = find_pallas_calls(fh.read(), filename=path)
        if not hits:
            continue
        if rel.startswith(PALLAS_ALLOWED):
            kernel_sites += len(hits)
        else:
            misplaced += [f"{rel}:{ln}" for ln in hits]
    return [Finding(
        family="lint", invariant="pallas_call_location", subject="src/repro",
        ok=not misplaced,
        detail=(f"{kernel_sites} pallas_call sites, all under kernels/"
                if not misplaced
                else f"pallas_call outside kernels/: {', '.join(misplaced)}"),
        data={"kernel_sites": kernel_sites, "misplaced": misplaced})]


def lint_serve_host_syncs(src_root: str | None = None) -> list:
    """Location rule: host syncs in ``serve/`` only in engine.py (the
    per-function count lives in syncaudit)."""
    src_root = src_root or _repo_src()
    serve = os.path.join(src_root, "serve")
    offenders = []
    for rel, path in _iter_sources(serve):
        with open(path) as fh:
            tree = ast.parse(fh.read(), filename=path)
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr in ("device_get", "block_until_ready"):
                if rel != "engine.py" or node.attr == "block_until_ready":
                    offenders.append(f"serve/{rel}:{node.lineno} "
                                     f"({node.attr})")
    return [Finding(
        family="lint", invariant="serve_host_sync_location",
        subject="serve/", ok=not offenders,
        detail=("host syncs only in engine.py" if not offenders
                else ", ".join(offenders)),
        data={"offenders": offenders})]


def lint_cli_flags() -> list:
    """Every ``--cce-*`` flag maps to a live ``CCEConfig`` field and its
    ``choices`` (if any) pass the dataclass validator."""
    from repro.kernels.ops import CCEConfig
    from repro.launch import cce_flags

    fields = {f.name for f in dataclasses.fields(CCEConfig)}
    problems = []
    for flag, (field, kwargs) in cce_flags._FLAGS.items():
        if field not in fields:
            problems.append(f"{flag} -> CCEConfig.{field} does not exist")
            continue
        for choice in kwargs.get("choices", ()) or ():
            try:
                CCEConfig(**{field: choice})
            except (ValueError, TypeError) as exc:
                problems.append(
                    f"{flag}: choice {choice!r} rejected by CCEConfig "
                    f"({exc})")
    try:  # the module's own validator must agree
        cce_flags._validate_flags()
    except Exception as exc:
        problems.append(f"_validate_flags() raised: {exc}")
    return [Finding(
        family="lint", invariant="cli_flags_match_dataclass",
        subject="launch/cce_flags", ok=not problems,
        detail=(f"{len(cce_flags._FLAGS)} flags map onto CCEConfig fields; "
                "all choices validate" if not problems
                else "; ".join(problems)),
        data={"flags": sorted(cce_flags._FLAGS),
              "problems": problems})]


def lint_all() -> list:
    return (lint_pallas_location() + lint_serve_host_syncs()
            + lint_cli_flags())
