"""Program-level memory-class proofs: every registered backend, every loss,
the scoring path, and the fused decode jit, AOT-lowered and classified.

Nothing executes on real data — each subject is lowered + compiled against
``ShapeDtypeStruct``s and its optimized HLO is classified with
:mod:`repro.analysis.checks.memclass`. Geometries are chosen small enough
to compile in seconds but *discriminating* (census budget < N·V), so a
dense materialization cannot hide inside legitimate buffer sizes.

The dense backend and the dense decode step are kept as positive controls:
the prover asserts they DO land in the O(N·V) class, which proves the
detector itself still discriminates (a prover that passes everything is
broken, not lucky).
"""

from __future__ import annotations

from repro.analysis.checks.common import Finding
from repro.analysis.checks.memclass import (DENSE_CLASS, census_budget,
                                            check_memory_class, class_rank,
                                            classify_hlo)

#: Backend/loss sweep geometry: budget = 4*max(N·D, V·D) = 8.4M elems vs
#: N·V = 33.5M (a 4x gap, so the verdict is sharp). D must satisfy
#: 2048·N <= budget — the cce_jax twin streams (N, 2048) vocabulary tiles,
#: which are legitimate CCE-class buffers only while that holds.
SWEEP_N, SWEEP_V, SWEEP_D = 2048, 16384, 128


def _lower_loss_text(loss_name, impl, n, v, d):
    import jax
    import jax.numpy as jnp

    from repro.core import cross_entropy
    from repro.losses import get_loss

    kwargs = {"z_loss": {"z_weight": 1e-4}, "focal": {"gamma": 2.0},
              "label_smoothing": {"eps": 0.1}}.get(loss_name, {})
    loss = get_loss(loss_name, **kwargs) if loss_name else None

    if loss_name == "seq_logprob":
        def f(E, C, x):
            return jnp.sum(cross_entropy(
                E.reshape(8, n // 8, d), C, x.reshape(8, n // 8),
                loss=loss, impl=impl))
    else:
        def f(E, C, x):
            kw = {"loss": loss} if loss else {}
            return cross_entropy(E, C, x, impl=impl, reduction="mean", **kw)

    g = jax.value_and_grad(f, argnums=(0, 1))
    E = jax.ShapeDtypeStruct((n, d), jnp.float32)
    C = jax.ShapeDtypeStruct((v, d), jnp.float32)
    x = jax.ShapeDtypeStruct((n,), jnp.int32)
    return jax.jit(g).lower(E, C, x).compile().as_text()


def prove_backends(n=SWEEP_N, v=SWEEP_V, d=SWEEP_D) -> list:
    """Observed memory class of each registered backend's value-and-grad
    program must not rank above the class the backend declares."""
    from repro.backends import base as backends

    findings = []
    for name in backends.list_backends():
        declared = backends.get(name).memory_class
        try:
            text = _lower_loss_text(None, name, n, v, d)
        except Exception as exc:
            findings.append(Finding(
                family="memclass", invariant="backend_class",
                subject=f"backend:{name}", ok=False,
                detail=f"lowering failed: {exc!r}"))
            continue
        observed = classify_hlo(text, n=n, v=v, d=d)
        findings.append(Finding(
            family="memclass", invariant="backend_class",
            subject=f"backend:{name}",
            ok=class_rank(observed) <= class_rank(declared),
            detail=(f"observed {observed}, declared {declared} "
                    f"(N={n} V={v} D={d})"),
            data={"observed": observed, "declared": declared,
                  "n": n, "v": v, "d": d}))
        if declared == DENSE_CLASS:
            # positive control: the detector must still SEE the dense class
            findings.append(Finding(
                family="memclass", invariant="detector_discriminates",
                subject=f"backend:{name}",
                ok=observed == DENSE_CLASS,
                detail=(f"dense control observed {observed}; a detector "
                        f"that cannot see {DENSE_CLASS} proves nothing"),
                data={"observed": observed}))
    return findings


def prove_losses(n=SWEEP_N, v=SWEEP_V, d=SWEEP_D, impl="cce_jax") -> list:
    """Every registered loss, lowered through ``cross_entropy`` on a
    CCE-class backend, stays in the CCE memory class."""
    from repro.losses import list_losses

    findings = []
    for loss_name in list_losses():
        try:
            finding = check_memory_class(
                _lower_loss_text(loss_name, impl, n, v, d),
                n=n, v=v, d=d, what=f"loss:{loss_name}(impl={impl})")
        except Exception as exc:
            finding = Finding(
                family="memclass", invariant="memory_class",
                subject=f"loss:{loss_name}", ok=False,
                detail=f"lowering failed: {exc!r}")
        findings.append(finding)
    return findings


def _reduced_cfg(vocab_size=32768):
    import dataclasses

    import repro.configs as configs
    return dataclasses.replace(configs.get_reduced_config("llama3_2_3b"),
                               dtype="float32", vocab_size=vocab_size)


def prove_scoring(batch=8, seq=64) -> list:
    """The CCE-backed scorer's compiled HLO stays in the CCE class at a
    discriminating vocabulary."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as T
    from repro.serve import scoring

    cfg = _reduced_cfg()
    n, v, d = batch * seq, cfg.padded_vocab_size, cfg.d_model
    params_sds = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0),
                                                  cfg))
    toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    findings = []
    try:
        fn = scoring.score_fn(cfg, impl="cce_jax")
        finding = check_memory_class(
            jax.jit(fn), params_sds, toks, toks, n=n, v=v, d=d,
            what="serve:scoring(cce_jax)")
    except Exception as exc:
        finding = Finding(family="memclass", invariant="memory_class",
                          subject="serve:scoring(cce_jax)", ok=False,
                          detail=f"lowering failed: {exc!r}")
    findings.append(finding)
    return findings


def prove_fused_decode(batch=512, vocab=32768, max_len=16) -> list:
    """The fused projection->sample decode jit contains no (B, V)-class
    buffer; the dense decode step at the same geometry is the control."""
    import jax

    from repro.models import transformer as T
    from repro.serve import engine as engine_mod
    from repro.serve import scheduler as sched_mod

    cfg = _reduced_cfg(vocab)
    b = batch
    n, v, d = b, cfg.padded_vocab_size, cfg.d_model
    params_sds = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0),
                                                  cfg))
    state_sds = jax.eval_shape(lambda: sched_mod.init_state(b, 8, 8))
    cache_sds = jax.eval_shape(lambda: T.init_cache(cfg, b, max_len))
    findings = []
    for wf in (False, True):
        subject = f"serve:decode_fused(filter={wf})"
        try:
            text = engine_mod._engine_step_fused.lower(
                params_sds, cache_sds, state_sds, None, cfg=cfg,
                max_len=max_len, with_filter=wf).compile().as_text()
            finding = check_memory_class(text, n=n, v=v, d=d,
                                         what=subject)
        except Exception as exc:
            finding = Finding(family="memclass", invariant="memory_class",
                              subject=subject, ok=False,
                              detail=f"lowering failed: {exc!r}")
        findings.append(finding)
    try:
        text = engine_mod._engine_step.lower(
            params_sds, cache_sds, state_sds, None, cfg=cfg,
            max_len=max_len).compile().as_text()
        observed = classify_hlo(text, n=n, v=v, d=d)
        findings.append(Finding(
            family="memclass", invariant="detector_discriminates",
            subject="serve:decode_dense",
            ok=observed == DENSE_CLASS,
            detail=(f"dense decode control observed {observed} at B={b} "
                    f"V={v} D={d} (budget {census_budget(n, v, d)})"),
            data={"observed": observed}))
    except Exception as exc:
        findings.append(Finding(
            family="memclass", invariant="detector_discriminates",
            subject="serve:decode_dense", ok=False,
            detail=f"lowering failed: {exc!r}"))
    return findings


def prove_all() -> list:
    return (prove_backends() + prove_losses() + prove_scoring()
            + prove_fused_decode())
