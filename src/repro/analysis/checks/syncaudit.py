"""Sync & retrace auditor for the serving/training step paths.

The engine's throughput contract (DESIGN.md §9) is *one* host
synchronization per decode step: ``Engine.step`` launches jitted work and
``Engine._sync`` pulls the small status vectors with a single unconditional
``jax.device_get`` (plus one batched fetch of finished rows behind an
early-out). Anything more — an extra ``device_get``, a stray
``block_until_ready``, a ``jax.jit`` re-entered per call with fresh Python
captures — silently serializes the pipeline or forces recompiles.

Two passes, both static:

  * **host-transfer count** (AST): every ``device_get`` /
    ``block_until_ready`` call site under ``serve/``, attributed to its
    enclosing function. The invariant: ``device_get`` appears only inside
    ``Engine._sync``, exactly one *unconditional* occurrence (before the
    first early ``return``), at most two total; ``block_until_ready``
    never appears in ``serve/``.
  * **retrace hygiene** (AST + jit introspection): every ``jax.jit`` call
    under ``serve/``/``train/`` is module-level, under an ``lru_cache``'d
    factory, or a one-time ``self.*`` assignment in ``__init__``; and each
    module-level jitted function closes over nothing (``co_freevars``
    empty) — a captured Python value is the classic accidental-retrace /
    stale-constant hazard.
"""

from __future__ import annotations

import ast
import os

from repro.analysis.checks.common import Finding

_SERVE_SYNC_ALLOWED = {("engine.py", "_sync")}


def _repo_src() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", ".."))  # .../src/repro


def _enclosing(stack) -> str:
    names = [n.name for n in stack
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    return ".".join(names) if names else "<module>"


def _scoped_walk(tree, visit):
    """Walk ``tree`` calling ``visit(node, stack)``; ``stack`` is the chain
    of enclosing function/class defs. A def's *decorators* are attributed
    to the OUTER scope (a module-level ``@functools.partial(jax.jit, ...)``
    is a module-level jit, not a call inside the function it decorates)."""
    scopes = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)

    def walk(node, stack):
        visit(node, stack)
        if isinstance(node, scopes):
            for deco in node.decorator_list:
                walk(deco, stack)
            inner = stack + [node]
            for child in ast.iter_child_nodes(node):
                if any(child is d for d in node.decorator_list):
                    continue
                walk(child, inner)
        else:
            for child in ast.iter_child_nodes(node):
                walk(child, stack)

    walk(tree, [])


def _call_sites(tree, attr_names):
    """[(attr, enclosing_fn, lineno, stack)] for Attribute calls."""
    sites = []

    def visit(node, stack):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in attr_names:
            sites.append((node.func.attr, _enclosing(stack),
                          node.lineno, list(stack)))

    _scoped_walk(tree, visit)
    return sites


def audit_host_transfers(serve_dir: str | None = None) -> list:
    """The "one device_get per step" invariant, statically."""
    serve_dir = serve_dir or os.path.join(_repo_src(), "serve")
    findings = []
    sync_counts: dict[str, list] = {}
    stray, busy_waits = [], []
    sync_fn_source = None

    for fname in sorted(os.listdir(serve_dir)):
        if not fname.endswith(".py"):
            continue
        path = os.path.join(serve_dir, fname)
        with open(path) as fh:
            source = fh.read()
        tree = ast.parse(source, filename=path)
        for attr, fn, lineno, _ in _call_sites(
                tree, {"device_get", "block_until_ready"}):
            if attr == "block_until_ready":
                busy_waits.append(f"{fname}:{lineno} in {fn}")
            else:
                leaf = fn.split(".")[-1]
                if (fname, leaf) in _SERVE_SYNC_ALLOWED:
                    sync_counts.setdefault(f"{fname}:{leaf}", []).append(
                        lineno)
                else:
                    stray.append(f"{fname}:{lineno} in {fn}")
        if fname == "engine.py":
            for node in ast.walk(tree):
                if isinstance(node, ast.FunctionDef) and \
                        node.name == "_sync":
                    sync_fn_source = node

    findings.append(Finding(
        family="sync", invariant="device_get_only_in_sync",
        subject="serve/", ok=not stray,
        detail=("every device_get lives in Engine._sync"
                if not stray else f"stray device_get: {', '.join(stray)}"),
        data={"stray": stray, "allowed": sorted(sync_counts)}))

    findings.append(Finding(
        family="sync", invariant="no_block_until_ready",
        subject="serve/", ok=not busy_waits,
        detail=("no block_until_ready in the serving path" if not busy_waits
                else f"block_until_ready at: {', '.join(busy_waits)}"),
        data={"sites": busy_waits}))

    # Exactly one *unconditional* pull per _sync call: one device_get
    # before the first early return, at most two total (the second is the
    # finished-row fetch behind ``if not rows: return []``).
    if sync_fn_source is None:
        findings.append(Finding(
            family="sync", invariant="one_device_get_per_step",
            subject="engine._sync", ok=False,
            detail="Engine._sync not found in serve/engine.py"))
    else:
        gets = [lineno for attr, fn, lineno, _ in _call_sites(
            sync_fn_source, {"device_get"})]
        returns = [n.lineno for n in ast.walk(sync_fn_source)
                   if isinstance(n, ast.Return)]
        first_return = min(returns) if returns else float("inf")
        unconditional = [ln for ln in gets if ln < first_return]
        ok = len(unconditional) == 1 and len(gets) <= 2
        findings.append(Finding(
            family="sync", invariant="one_device_get_per_step",
            subject="engine._sync", ok=ok,
            detail=(f"{len(unconditional)} unconditional device_get "
                    f"(require exactly 1), {len(gets)} total "
                    f"(require <= 2) at lines {gets}"),
            data={"device_get_lines": gets,
                  "first_return_line": returns and min(returns)}))
    return findings


# ---------------------------------------------------------------------------
# Retrace hygiene
# ---------------------------------------------------------------------------

def _jit_call_sites(tree):
    """[(enclosing_fn, lineno, stack)] of ``jax.jit(...)`` call sites,
    including decorator positions."""
    sites = []

    def is_jit(node):
        # jax.jit(...) or functools.partial(jax.jit, ...)
        if not isinstance(node, ast.Call):
            return False
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr == "jit":
            return True
        if isinstance(fn, ast.Attribute) and fn.attr == "partial":
            return any(isinstance(a, ast.Attribute) and a.attr == "jit"
                       for a in node.args)
        return False

    def visit(node, stack):
        if is_jit(node):
            sites.append((_enclosing(stack), node.lineno, list(stack)))

    _scoped_walk(tree, visit)
    return sites


def _cached_factory(stack) -> bool:
    """Enclosing def carries functools.lru_cache / functools.cache."""
    for node in stack:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for deco in node.decorator_list:
            target = deco.func if isinstance(deco, ast.Call) else deco
            name = target.attr if isinstance(target, ast.Attribute) \
                else getattr(target, "id", "")
            if name in ("lru_cache", "cache"):
                return True
    return False


def _init_assignment(stack) -> bool:
    """Call happens inside ``__init__`` (one jit per object, not per step)."""
    return any(isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
               and n.name == "__init__" for n in stack)


def audit_retrace(dirs=("serve", "train")) -> list:
    """jax.jit call-site placement + closure-capture audit."""
    findings = []
    misplaced = []
    scanned = 0
    for sub in dirs:
        root = os.path.join(_repo_src(), sub)
        if not os.path.isdir(root):
            continue
        for fname in sorted(os.listdir(root)):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(root, fname)
            with open(path) as fh:
                tree = ast.parse(fh.read(), filename=path)
            for fn, lineno, stack in _jit_call_sites(tree):
                scanned += 1
                if fn == "<module>" or _cached_factory(stack) \
                        or _init_assignment(stack):
                    continue
                misplaced.append(f"{sub}/{fname}:{lineno} in {fn}")
    findings.append(Finding(
        family="sync", invariant="jit_placement", subject="serve/ train/",
        ok=not misplaced,
        detail=(f"{scanned} jax.jit sites: all module-level, lru_cached, "
                "or one-time __init__ construction" if not misplaced
                else f"per-call jit (retrace risk): {', '.join(misplaced)}"),
        data={"scanned": scanned, "misplaced": misplaced}))

    # Introspect the live jitted step functions: no Python-value captures.
    captured = []
    checked = []
    import importlib
    for modname in ("repro.serve.engine", "repro.serve.scheduler"):
        mod = importlib.import_module(modname)
        for attr in sorted(vars(mod)):
            obj = getattr(mod, attr)
            wrapped = getattr(obj, "__wrapped__", None)
            if wrapped is None or not hasattr(obj, "lower"):
                continue  # not a jit wrapper
            code = getattr(wrapped, "__code__", None)
            if code is None:
                continue
            checked.append(f"{modname}.{attr}")
            if code.co_freevars:
                captured.append(
                    f"{modname}.{attr} closes over {code.co_freevars}")
    findings.append(Finding(
        family="sync", invariant="no_jit_captures",
        subject="engine/scheduler jits", ok=not captured,
        detail=(f"{len(checked)} jitted step functions close over nothing"
                if not captured else "; ".join(captured)),
        data={"checked": checked, "captured": captured}))
    return findings


def audit_all() -> list:
    return audit_host_transfers() + audit_retrace()


def audit_source(source: str, *, filename: str = "engine.py",
                 sync_fn: str = "_sync") -> list:
    """Audit a source string as if it were ``serve/<filename>`` — the
    negative-test hook: feed a step path with an extra device_get and the
    auditor must flag it."""
    tree = ast.parse(source, filename=filename)
    stray, gets_in_sync, busy = [], [], []
    for attr, fn, lineno, _ in _call_sites(
            tree, {"device_get", "block_until_ready"}):
        leaf = fn.split(".")[-1]
        if attr == "block_until_ready":
            busy.append(f"{filename}:{lineno} in {fn}")
        elif leaf == sync_fn:
            gets_in_sync.append(lineno)
        else:
            stray.append(f"{filename}:{lineno} in {fn}")
    findings = [Finding(
        family="sync", invariant="device_get_only_in_sync",
        subject=filename, ok=not stray,
        detail=("ok" if not stray
                else f"stray device_get: {', '.join(stray)}"),
        data={"stray": stray}),
        Finding(
        family="sync", invariant="no_block_until_ready",
        subject=filename, ok=not busy,
        detail="ok" if not busy else f"block_until_ready: {busy}",
        data={"sites": busy}),
        Finding(
        family="sync", invariant="one_device_get_per_step",
        subject=f"{filename}:{sync_fn}",
        ok=len(gets_in_sync) <= 2,
        detail=f"{len(gets_in_sync)} device_get in {sync_fn} "
               f"(require <= 2)",
        data={"lines": gets_in_sync})]
    return findings
