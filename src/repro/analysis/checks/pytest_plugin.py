"""Pytest plugin exposing the static checkers as fixtures.

Registered from ``tests/conftest.py`` via
``pytest_plugins = ("repro.analysis.checks.pytest_plugin",)``.

Fixtures (all plain callables — the fixture indirection keeps test modules
free of deep ``repro.analysis.checks.*`` import paths and gives one seam
for future session-scoped caching of expensive lowerings):

  assert_memory_class(target, *args, n=, v=, d=, max_class=)
      raise if the compiled program leaves the CCE memory class
  check_memory_class(...)
      same evaluation, returns the Finding instead of raising
  extract_pallas_calls(fn, *example_args, **kwargs)
      statically extracted PallasCallInfo records
  assert_kernel_contracts(fn, *example_args, claimed_bytes=, **kwargs)
      extract + verify all pallas launch contracts, raise on violation
"""

from __future__ import annotations

import pytest


@pytest.fixture
def assert_memory_class():
    from repro.analysis.checks import memclass
    return memclass.assert_memory_class


@pytest.fixture
def check_memory_class():
    from repro.analysis.checks import memclass
    return memclass.check_memory_class


@pytest.fixture
def extract_pallas_calls():
    from repro.analysis.checks import pallas
    return pallas.extract_pallas_calls


@pytest.fixture
def assert_kernel_contracts():
    from repro.analysis.checks import pallas
    return pallas.assert_kernel_contracts
