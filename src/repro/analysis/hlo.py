"""Post-optimization HLO text analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body exactly once, so
for scan-over-layers models it under-reports FLOPs/bytes by ~num_layers x.
This analyzer parses ``compiled.as_text()`` and computes, with *while-loop
trip-count multipliers* applied recursively:

  * dot FLOPs (2 * prod(output dims) * prod(contraction dims)),
  * an HBM-traffic estimate (operand+output bytes at fusion/instruction
    granularity, skipping pure layout ops),
  * per-collective wire bytes (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), reported both raw (sum of operand
    sizes, as the assignment specifies) and ring-algorithm adjusted.

Shapes in post-SPMD HLO are per-device, so all numbers are per-device.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "u1": 0.125, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# header params may contain nested tuples, so match greedily to "-> ... {"
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*->.*\{\s*$")
# the output type may be a tuple containing /*index=N*/ comments (with '='),
# so match it lazily up to the first " opcode(" boundary.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that are pure layout/bookkeeping — excluded from the traffic estimate
_SKIP_TRAFFIC = {
    "parameter", "constant", "iota", "bitcast", "tuple", "get-tuple-element",
    "reshape", "after-all", "partition-id", "replica-id",
}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str):
    """First array shape in the string -> (dtype, [dims])."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def array_shape_census(hlo_text: str, top: int = 8) -> list:
    """Largest *distinct* array shapes in the module: [(elems, "dtype[dims]")]
    sorted descending.

    A cheap, layout-independent detector for accidental materialization:
    a loss in CCE's O(N·D + V·D) memory class must not contain any
    N×V-element buffer anywhere in its optimized HLO, while the dense
    baseline always does (``benchmarks/loss_zoo_memory.py``).
    """
    seen: dict[str, float] = {}
    for dtype, dims in _SHAPE_RE.findall(hlo_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        seen[f"{dtype}[{dims}]"] = n
    return sorted(((n, k) for k, n in seen.items()),
                  key=lambda p: -p[0])[:top]


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    rest: str        # operand list + attributes (may span the line only)
    is_root: bool = False


def parse_computations(hlo_text: str) -> tuple:
    """(comps, types): comps name -> list[Instr]; types name -> dict of
    instruction-name -> output type string (the per-computation symbol
    table — scheduled HLO prints operands without inline types)."""
    comps: dict[str, list[Instr]] = {}
    types: dict[str, dict] = {}
    current = None
    for line in hlo_text.splitlines():
        if current is None:
            m = _COMP_START.match(line.strip())
            if m and "{" in line:
                current = m.group(1)
                comps[current] = []
                types[current] = {}
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4),
                        is_root=line.lstrip().startswith("ROOT"))
            comps[current].append(ins)
            types[current][ins.name] = ins.out_type
    return comps, types


def _called_comps(instr: Instr) -> list:
    """computation names referenced via calls=/body=/condition=/branches=
    or to_apply= (we exclude to_apply: reduce/sort lambdas are tiny)."""
    out = []
    for attr in ("body", "condition"):
        m = re.search(attr + r"=%?([\w\.\-_]+)", instr.rest)
        if m:
            out.append((attr, m.group(1)))
    m = re.search(r"(?:calls|fusion)=%?([\w\.\-_]+)", instr.rest)
    if m:
        out.append(("call", m.group(1)))
    m = re.search(r"branches=\{([^}]*)\}", instr.rest)
    if m:
        for b in m.group(1).split(","):
            out.append(("branch", b.strip().lstrip("%")))
    return out


_NAME_RE = re.compile(r"%([\w\.\-_]+)")

_ATTR_KEYWORDS = (
    "), metadata=", "), backend_config=", "), calls=", "), to_apply=",
    "), body=", "), condition=", "), dimensions=", "), replica_groups=",
    "), channel_id=", "), sharding=", "), source_target_pairs=",
    "), slice=", "), kind=", "), lhs_contracting_dims=", "), custom_call",
    "), branches=", "), index=")


def _operand_segment(instr: Instr) -> str:
    """The operand-list part of the instruction text (before attributes)."""
    text = instr.rest
    cut = len(text)
    for kw in _ATTR_KEYWORDS:
        i = text.find(kw)
        if 0 <= i < cut:
            cut = i + 1  # keep the ")"
    return text[:cut]


def _operand_names(instr: Instr) -> list:
    return _NAME_RE.findall(_operand_segment(instr))


def _operand_types(instr: Instr, symtab: dict) -> list:
    """Output-type strings of this instruction's operands."""
    return [symtab[n] for n in _operand_names(instr) if n in symtab]


def _dot_flops(instr: Instr, symtab: dict) -> float:
    _, out_dims = _shape_elems(instr.out_type)
    ops = _operand_types(instr, symtab)
    if not ops:
        return 0.0
    _, lhs_dims = _shape_elems(ops[0])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contract = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            contract *= lhs_dims[int(i)]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    return 2.0 * out_elems * contract


# ---------------------------------------------------------------------------
# Slice-accurate HBM traffic charging.
#
# XLA buffer-aliases ``dynamic-update-slice`` in place inside while loops
# (lax.scan carry/stacking), and a fused ``dynamic-slice`` reads only the
# sliced region. Charging such instructions at full-buffer size inflates the
# traffic of scan-heavy models by the trip count (~100x for a 64-chunk
# recurrence): a 672 MB stacked buffer written via a 10.5 MB DUS per trip
# must be charged 10.5 MB, not 672 MB.
#
# Dtype-cast normalization: the CPU backend has no native bf16 FMA, so it
# rewrites every bf16 dot/scatter as convert(bf16->f32) + f32 op (+ convert
# back), materializing f32 copies of every large tensor. On the TPU target
# none of that traffic exists — the MXU consumes bf16 directly and pure
# casts always fuse into their producer/consumer. The traffic model
# therefore charges standalone ``convert``s (and cast-only fusions) zero
# and resolves operands through cast chains to their *narrow-side* bytes.
# ---------------------------------------------------------------------------

_PARAM_IDX_RE = re.compile(r"^\s*(\d+)\s*\)")

_CAST_CHAIN_OPS = ("convert", "bitcast", "reshape", "copy")


def _is_cast_only_fusion(finstrs: list) -> bool:
    return all(i.opcode in _CAST_CHAIN_OPS or i.opcode in
               ("parameter", "constant", "tuple")
               for i in finstrs)


def _effective_bytes(name: str, by_name: dict, symtab: dict,
                     comps: dict, types: dict, depth: int = 0) -> float:
    """Bytes a consumer actually moves for operand ``name``: dtype-cast
    chains are resolved to the narrowest tensor along the chain (what the
    TPU fusion boundary would read)."""
    t = symtab.get(name)
    if t is None:
        return 0.0
    b = _shape_bytes(t)
    if depth > 6:
        return b
    ins = by_name.get(name)
    if ins is None:
        return b
    if ins.opcode == "convert":
        ops = _operand_names(ins)
        if ops:
            return min(b, _effective_bytes(ops[0], by_name, symtab, comps,
                                           types, depth + 1))
    if ins.opcode == "fusion":
        for kind, c in _called_comps(ins):
            if kind == "call" and _is_cast_only_fusion(comps.get(c, [])):
                inner = [
                    _effective_bytes(opn, by_name, symtab, comps, types,
                                     depth + 1)
                    for opn in _operand_names(ins) if opn in symtab]
                if inner:
                    return min(b, min(inner))
    return b


def _root_write_bytes(comp_instrs: list, ftypes: dict) -> float | None:
    """Bytes actually *written* by a fused computation's root, following
    bitcast/reshape chains and resolving DUS roots to their update size.
    None => unknown (charge full output)."""
    by_name = {i.name: i for i in comp_instrs}
    root = next((i for i in comp_instrs if i.is_root), None)
    if root is None:
        return None

    def written(ins, depth=0) -> float | None:
        if depth > 8:
            return None
        if ins.opcode in ("bitcast", "reshape", "copy"):
            ops = _operand_names(ins)
            if ops and ops[0] in by_name:
                return written(by_name[ops[0]], depth + 1)
            return None
        if ins.opcode == "dynamic-update-slice":
            ops = _operand_names(ins)
            if len(ops) >= 2 and ops[1] in ftypes:
                return _shape_bytes(ftypes[ops[1]])
            return None
        if ins.opcode == "tuple":
            total = 0.0
            for opn in _operand_names(ins):
                if opn in by_name:
                    w = written(by_name[opn], depth + 1)
                    total += (w if w is not None
                              else _shape_bytes(ftypes.get(opn, "")))
                else:
                    total += _shape_bytes(ftypes.get(opn, ""))
            return total
        return None  # ordinary root: full output charge

    return written(root)


def _fusion_traffic(instr: Instr, fused: str, comps: dict, types: dict,
                    symtab: dict, by_name: dict | None = None) -> float:
    """Charged HBM bytes for one fusion boundary (reads + writes)."""
    full_out = _shape_bytes(instr.out_type)
    op_names = _operand_names(instr)
    op_bytes = [_shape_bytes(symtab[n]) for n in op_names if n in symtab]
    finstrs = comps.get(fused)
    if not finstrs:
        return full_out + sum(op_bytes)
    if _is_cast_only_fusion(finstrs):
        return 0.0          # pure dtype/layout cast: fused away on TPU
    by_name = by_name or {}
    ftypes = types.get(fused, {})

    # map fusion operands (positional) to parameter names inside
    params_by_idx: dict[int, str] = {}
    for ins in finstrs:
        if ins.opcode == "parameter":
            m = _PARAM_IDX_RE.match(ins.rest)
            if m:
                params_by_idx[int(m.group(1))] = ins.name
    # consumers of each parameter: (instr, operand position)
    consumers: dict[str, list] = {}
    for ins in finstrs:
        if ins.opcode == "parameter":
            continue
        for pos, opn in enumerate(_operand_names(ins)):
            if opn in ftypes:
                consumers.setdefault(opn, []).append((ins, pos))

    reads = 0.0
    for pos, name in enumerate(op_names):
        if name not in symtab:
            continue
        full = _effective_bytes(name, by_name, symtab, comps, types)
        pname = params_by_idx.get(pos)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(i.opcode == "dynamic-slice" for i, _ in cons):
            # only sliced regions are read
            charged = sum(_shape_bytes(i.out_type) for i, _ in cons)
            reads += min(charged, full)
        elif cons and all(i.opcode == "dynamic-update-slice" and p == 0
                          for i, p in cons):
            # in-place accumulator: region outside the update is untouched
            reads += 0.0
        else:
            reads += full
    writes = _root_write_bytes(finstrs, ftypes)
    if writes is None:
        writes = full_out
    return reads + min(writes, full_out)


def _plain_instr_traffic(instr: Instr, symtab: dict, by_name: dict,
                         comps: dict, types: dict) -> float:
    """Charged bytes for a non-fusion instruction."""
    out_b = _shape_bytes(instr.out_type)
    if instr.opcode == "convert":
        return 0.0                             # fused away on the TPU target
    if instr.opcode == "dynamic-slice":
        return 2.0 * out_b                     # read slice + write slice
    if instr.opcode == "dynamic-update-slice":
        ops = _operand_names(instr)
        upd = (_shape_bytes(symtab[ops[1]])
               if len(ops) >= 2 and ops[1] in symtab else out_b)
        return 2.0 * upd                       # read update + write region
    return out_b + sum(
        _effective_bytes(n, by_name, symtab, comps, types)
        for n in _operand_names(instr) if n in symtab)


def _trip_count(cond_instrs: list) -> int:
    """Heuristic scan trip count: the largest integer constant compared in
    the loop condition (lax.scan lowers to `lt(i, N)`)."""
    best = 1
    for ins in cond_instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def analyze(hlo_text: str, entry: str | None = None) -> dict:
    """Whole-module analysis with while-loop multipliers.

    Returns dict(flops, traffic_bytes, collective_bytes,
                 collective_wire_bytes, collectives={op: bytes},
                 collective_counts={op: n}).
    """
    comps, types = parse_computations(hlo_text)
    if not comps:
        return {"flops": 0, "traffic_bytes": 0, "collective_bytes": 0,
                "collective_wire_bytes": 0, "collectives": {},
                "collective_counts": {}}
    if entry is None:
        # entry computation: the one never called by others, largest
        called = set()
        for instrs in comps.values():
            for ins in instrs:
                for _, c in _called_comps(ins):
                    called.add(c)
        entries = [c for c in comps if c not in called]
        entry = max(entries, key=lambda c: len(comps[c])) if entries \
            else next(iter(comps))

    memo: dict[str, dict] = {}

    def group_size(instr):
        m = re.search(r"replica_groups=\{\{([^}]*)\}", instr.rest)
        if m:
            return max(1, m.group(1).count(",") + 1)
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.rest)
        if m:
            return max(1, int(m.group(2)))
        return 2

    def visit(name: str) -> dict:
        if name in memo:
            return memo[name]
        acc = {"flops": 0.0, "traffic_bytes": 0.0, "collective_bytes": 0.0,
               "collective_wire_bytes": 0.0,
               "collectives": defaultdict(float),
               "collective_counts": defaultdict(float)}
        memo[name] = acc  # guard vs accidental cycles
        symtab = types.get(name, {})
        by_name = {i.name: i for i in comps.get(name, [])}
        for ins in comps.get(name, []):
            op = ins.opcode
            if op == "dot":
                acc["flops"] += _dot_flops(ins, symtab)
            if op == "while":
                body = cond = None
                for kind, c in _called_comps(ins):
                    if kind == "body":
                        body = c
                    elif kind == "condition":
                        cond = c
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                for sub in (body, cond):
                    if sub:
                        child = visit(sub)
                        for k in ("flops", "traffic_bytes",
                                  "collective_bytes",
                                  "collective_wire_bytes"):
                            acc[k] += trips * child[k]
                        for cname, v in child["collectives"].items():
                            acc["collectives"][cname] += trips * v
                        for cname, v in child["collective_counts"].items():
                            acc["collective_counts"][cname] += trips * v
                continue
            fused_comp = None
            if op in ("fusion", "call", "conditional", "async-start"):
                # fusions/calls contribute their inner FLOPs and collectives,
                # but NOT inner traffic: everything inside a fusion lives in
                # registers — the HBM boundary is the fusion instruction
                # itself (its operands/outputs, charged slice-accurately
                # below via _fusion_traffic).
                for kind, c in _called_comps(ins):
                    child = visit(c)
                    if op == "fusion" and kind == "call":
                        fused_comp = c
                    for k in ("flops", "collective_bytes",
                              "collective_wire_bytes"):
                        acc[k] += child[k]
                    if op in ("conditional",):
                        acc["traffic_bytes"] += child["traffic_bytes"]
                    for cname, v in child["collectives"].items():
                        acc["collectives"][cname] += v
                    for cname, v in child["collective_counts"].items():
                        acc["collective_counts"][cname] += v
            base = next((c for c in COLLECTIVES
                         if op == c or op.startswith(c + "-")
                         or op == c + "-start"), None)
            if base is not None and not op.endswith("-done"):
                opb = sum(_shape_bytes(t)
                          for t in _operand_types(ins, symtab))
                acc["collective_bytes"] += opb
                acc["collectives"][base] += opb
                acc["collective_counts"][base] += 1
                g = group_size(ins)
                ring = {(  # per-device wire bytes, ring algorithms
                    "all-gather"): opb * (g - 1),
                    "all-reduce": 2.0 * opb * (g - 1) / g,
                    "reduce-scatter": opb * (g - 1) / g,
                    "all-to-all": opb * (g - 1) / g,
                    "collective-permute": opb,
                }[base]
                acc["collective_wire_bytes"] += ring
            if op not in _SKIP_TRAFFIC:
                if op == "fusion" and fused_comp is not None:
                    acc["traffic_bytes"] += _fusion_traffic(
                        ins, fused_comp, comps, types, symtab, by_name)
                else:
                    acc["traffic_bytes"] += _plain_instr_traffic(
                        ins, symtab, by_name, comps, types)
        acc["collectives"] = dict(acc["collectives"])
        acc["collective_counts"] = dict(acc["collective_counts"])
        return acc

    out = visit(entry)
    out["entry"] = entry
    return out
