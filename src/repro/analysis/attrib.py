"""Per-instruction attribution of the roofline terms from lowered HLO.

The dry-run gives one number per term; hillclimbing needs to know *which*
instructions dominate. This walks the post-SPMD module exactly like
``analysis.hlo.analyze`` (same trip-count multipliers, same slice-accurate
traffic charging) but keeps per-instruction rows so the top-k offenders can
be printed per term.

Usage (CLI):
  PYTHONPATH=src python -m repro.analysis.attrib --arch rwkv6_3b \
      --shape train_4k [--mesh single] [--top 20] [--hlo-out FILE]
"""

from __future__ import annotations

import argparse
from collections import defaultdict

from repro.analysis import hlo as H


def attribute(hlo_text: str) -> dict:
    """Returns {"traffic": [(bytes, comp, instr, opcode, type)...],
    "flops": [...], "collective": [...]} sorted descending, with while-loop
    trip multipliers applied."""
    comps, types = H.parse_computations(hlo_text)
    called = set()
    for instrs in comps.values():
        for ins in instrs:
            for _, c in H._called_comps(ins):
                called.add(c)
    entries = [c for c in comps if c not in called]
    entry = max(entries, key=lambda c: len(comps[c])) if entries \
        else next(iter(comps))

    traffic, flops, coll = [], [], []

    def visit(name: str, mult: float, in_fusion: bool = False):
        symtab = types.get(name, {})
        by_name = {i.name: i for i in comps.get(name, [])}
        for ins in comps.get(name, []):
            op = ins.opcode
            if op == "dot":
                flops.append((H._dot_flops(ins, symtab) * mult, name,
                              ins.name, op, ins.out_type[:70]))
            if op == "while":
                body = cond = None
                for kind, c in H._called_comps(ins):
                    if kind == "body":
                        body = c
                    elif kind == "condition":
                        cond = c
                trips = H._trip_count(comps.get(cond, [])) if cond else 1
                for sub in (body, cond):
                    if sub:
                        visit(sub, mult * trips, in_fusion)
                continue
            fused_comp = None
            if op in ("fusion", "call", "conditional", "async-start"):
                for kind, c in H._called_comps(ins):
                    if op == "fusion" and kind == "call":
                        fused_comp = c
                    # traffic is charged at the fusion boundary only (same
                    # rule as hlo.analyze): everything inside lives in
                    # registers/VMEM
                    visit(c, mult, in_fusion or op == "fusion")
            base = next((c for c in H.COLLECTIVES
                         if op == c or op.startswith(c + "-")
                         or op == c + "-start"), None)
            if base is not None and not op.endswith("-done"):
                b = sum(H._shape_bytes(t)
                        for t in H._operand_types(ins, symtab))
                coll.append((b * mult, name, ins.name, base,
                             ins.out_type[:70]))
            if op not in H._SKIP_TRAFFIC and not in_fusion:
                if op == "fusion" and fused_comp is not None:
                    b = H._fusion_traffic(ins, fused_comp, comps, types,
                                          symtab, by_name)
                else:
                    b = H._plain_instr_traffic(ins, symtab, by_name,
                                               comps, types)
                traffic.append((b * mult, name, ins.name, op,
                                ins.out_type[:70]))

    visit(entry, 1.0)
    for rows in (traffic, flops, coll):
        rows.sort(key=lambda r: -r[0])
    return {"traffic": traffic, "flops": flops, "collective": coll}


def summarize(hlo_text: str, top: int = 20) -> str:
    rows = attribute(hlo_text)
    out = []
    for term, unit, scale in (("traffic", "GB", 1e9), ("flops", "GFLOP", 1e9),
                              ("collective", "GB", 1e9)):
        data = rows[term]
        total = sum(r[0] for r in data)
        out.append(f"== {term}: total {total/scale:.1f} {unit} ==")
        for val, comp, name, op, typ in data[:top]:
            out.append(f"  {val/scale:12.2f} {unit[:2]} {op:26s} "
                       f"{comp[:28]:30s} {name[:34]:36s} {typ}")
        # aggregate by opcode for a quick shape-of-the-problem view
        agg = defaultdict(float)
        for val, _, _, op, _ in data:
            agg[op] += val
        tops = sorted(agg.items(), key=lambda kv: -kv[1])[:8]
        out.append("  by opcode: " + ", ".join(
            f"{op}={v/scale:.1f}" for op, v in tops))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--hlo-out", default=None,
                    help="also dump the compiled HLO text here")
    ap.add_argument("--hlo-in", default=None,
                    help="analyze a saved HLO text instead of compiling")
    args = ap.parse_args()

    if args.hlo_in:
        text = open(args.hlo_in).read()
    else:
        # late import: sets XLA_FLAGS for 512 host devices
        from repro.launch import dryrun as D
        text = D.lower_cell_hlo(args.arch, args.shape,
                                multi_pod=args.mesh == "multi")
        if args.hlo_out:
            with open(args.hlo_out, "w") as f:
                f.write(text)
    print(summarize(text, args.top))


if __name__ == "__main__":
    main()
