"""Data pipeline: deterministic, shard-aware, checkpointable-by-step."""
from repro.data.synthetic import DataConfig, SyntheticLM  # noqa: F401
