"""Deterministic synthetic LM data pipeline.

Design for fault tolerance: a batch is a *pure function of the step index*
(``batch_at(step)``), so the entire data-iterator state that needs
checkpointing is one integer. On elastic restarts with a different data
shard count, ``shard_batch`` re-slices the same global batch — no drift.

The token stream is a seeded order-1 Markov chain over the vocabulary with a
Zipf-ish marginal, which gives the loss a learnable structure (benchmarks
fig4 uses it to compare convergence of CCE vs. the dense baseline).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.kernels.ref import IGNORE_INDEX


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ignore_fraction: float = 0.0   # fraction of label positions masked
    zipf_alpha: float = 1.1
    markov_states: int = 64        # mixing states for structure


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipf marginal over the vocab, fixed per dataset seed.
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._marginal = p / p.sum()
        # Markov mixing: each state biases a contiguous vocab band.
        self._state_shift = rng.integers(0, v, size=cfg.markov_states)

    def batch_at(self, step: int) -> dict:
        """Global batch for ``step``: tokens/labels (B, S) int32 numpy."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed + 1) * 1_000_003 + step)
        b, s = cfg.global_batch, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self._marginal)
        state = rng.integers(0, cfg.markov_states, size=(b, 1))
        toks = (base + self._state_shift[state]) % cfg.vocab_size
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        if cfg.ignore_fraction > 0:
            mask = rng.random((b, s)) < cfg.ignore_fraction
            labels = np.where(mask, IGNORE_INDEX, labels)
        return {"tokens": tokens, "labels": labels}

    def shard_batch(self, batch: dict, shard: int, num_shards: int) -> dict:
        b = self.cfg.global_batch
        assert b % num_shards == 0, (b, num_shards)
        lo = shard * (b // num_shards)
        hi = lo + b // num_shards
        return {k: v[lo:hi] for k, v in batch.items()}


def pack_documents(doc_lengths, seq_len, *, pad_to_full=True):
    """First-fit packing of variable-length docs into fixed-length rows.

    Returns a list of rows, each a list of (doc_id, start_in_row, length).
    Used by tests/benchmarks to exercise IGNORE_INDEX semantics the way a
    real packed pipeline would (cross-document label masking).
    """
    rows: list[list[tuple]] = []
    space: list[int] = []
    for doc_id, ln in enumerate(doc_lengths):
        ln = min(ln, seq_len)
        for i, free in enumerate(space):
            if free >= ln:
                rows[i].append((doc_id, seq_len - free, ln))
                space[i] -= ln
                break
        else:
            rows.append([(doc_id, 0, ln)])
            space.append(seq_len - ln)
    return rows


def packed_labels(rows, seq_len):
    """Label mask for packed rows: positions crossing doc boundaries (and
    padding) get IGNORE_INDEX. Returns (num_rows, seq_len) int8 validity."""
    valid = np.zeros((len(rows), seq_len), np.int8)
    for r, row in enumerate(rows):
        for _, start, ln in row:
            # last token of each doc predicts across a boundary -> invalid
            valid[r, start:start + ln - 1] = 1
    return valid
