"""Backend substrate: every realization of the CCE primitive as a
capability-declaring registered class.

The paper's contribution is a *primitive* — per-token ``(lse, pick
[, sum_logits])`` — with many interchangeable realizations: the Pallas TPU
kernels, the portable ``lax.scan`` twin, the dense/chunked/liger paper
baselines, and (through :mod:`repro.core.vocab_parallel`) the sharded
combine of any of them. What each realization *can* do differs:

  * only some expose the differentiable ``lse_pick`` primitive with
    arbitrary cotangents (what every :mod:`repro.losses` entry needs);
  * only some produce the third ``sum_logits`` output (label smoothing);
  * one (liger) computes gradients in its forward and therefore owns the
    loss reduction — the paper's composability caveat (§2);
  * only primitive-capable backends can run under the vocab-parallel
    shard_map combine.

Instead of every call site re-encoding those quirks as string ``if/elif``
chains, each backend declares them as class attributes and
:func:`resolve` picks (or validates) a backend against a
:class:`Requirements` — raising errors that enumerate which registered
backends *do* satisfy the request.

Registry pattern mirrors :mod:`repro.losses`: ``@register("name")`` on a
:class:`Backend` subclass; singletons, looked up by :func:`get` /
:func:`resolve`; ``python -m repro.backends`` prints the capability matrix.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.ops import CCEConfig
from repro.kernels.ref import IGNORE_INDEX

_REGISTRY: Dict[str, "Backend"] = {}


def register(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate a :class:`Backend` subclass into the
    registry under ``name``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls
    return deco


class BackendResolutionError(ValueError):
    """A backend (or ``impl="auto"``) cannot satisfy the call's
    requirements. The message enumerates the backends that can."""


@dataclasses.dataclass(frozen=True)
class Requirements:
    """What a call site needs from a backend.

    custom_cotangents — the differentiable ``lse_pick`` primitive accepting
        arbitrary cotangents (every registry loss, and any weighted or
        vocab-parallel call).
    sum_logits — the third per-token output (losses with
        ``needs_sum_logits``, e.g. label smoothing).
    mesh — the backend must run inside the vocab-parallel shard_map body.
    reduction — the reduction the caller will apply; reduction-owning
        backends (liger) only admit "mean". ``None`` skips the check.
    """
    custom_cotangents: bool = False
    sum_logits: bool = False
    mesh: bool = False
    reduction: Optional[str] = None


class Backend:
    """One realization of the CCE primitive, with declared capabilities.

    Class attributes are the capability matrix (see README); subclasses
    implement :meth:`lse_pick` (primitive-capable backends) and/or
    :meth:`nll` / :meth:`reduced_loss` (NLL-only baselines).
    """
    name: str = ""
    description: str = ""
    memory_class: str = "?"
    # the differentiable (lse, pick[, sum]) primitive with arbitrary
    # cotangents — prerequisite for every repro.losses entry
    supports_custom_cotangents: bool = False
    # third per-token output: sum of softcapped logits over the vocabulary
    supports_sum_logits: bool = False
    # gradients computed in the forward => the op owns the loss reduction
    owns_reduction: bool = False
    # usable as the per-shard body of the vocab-parallel shard_map combine
    supports_mesh: bool = False
    # platforms where impl="auto" prefers this backend
    preferred_platforms: tuple = ()
    # tie-break among platform-matching candidates (higher wins)
    priority: int = 0
    # shard_map varying-manual-axes checking (False for the Pallas
    # interpret path, whose kernel-internal iotas trip the checker; the
    # pessimistic transpose then inserts the replication psums itself)
    shard_map_check_vma: bool = True

    # -- uniform interface -------------------------------------------------

    def lse_pick(self, E, C, x, cfg: CCEConfig, *,
                 with_sum_logits: bool = False):
        """(lse, pick[, sum_logits]) per token, shapes like ``x``."""
        raise BackendResolutionError(self._cannot(
            Requirements(custom_cotangents=True,
                         sum_logits=with_sum_logits)))

    def nll(self, E, C, x, cfg: CCEConfig, *, num_chunks: int = 8):
        """Per-token NLL (IGNORE_INDEX positions get 0). Default lowers
        onto :meth:`lse_pick`; NLL-only baselines override."""
        lse, pick = self.lse_pick(E, C, x, cfg)
        return jnp.where(x == IGNORE_INDEX, 0.0, lse - pick)

    def reduced_loss(self, E, C, x, cfg: CCEConfig, *, num_chunks: int = 8):
        """Scalar mean NLL for reduction-owning backends (liger)."""
        raise BackendResolutionError(
            f"backend {self.name!r} does not own its reduction; "
            f"use nll()/lse_pick() and reduce explicitly")

    # -- capability checking ----------------------------------------------

    def unsupported(self, req: Requirements) -> list:
        """Human-readable reasons this backend cannot serve ``req``
        (empty list == satisfies)."""
        reasons = []
        if req.custom_cotangents and not self.supports_custom_cotangents:
            reasons.append("no differentiable lse_pick primitive with "
                           "custom cotangents (required by registry "
                           "losses, per-token weights, and the "
                           "vocab-parallel combine)")
        if req.sum_logits and not self.supports_sum_logits:
            reasons.append("no sum_logits third output")
        if req.mesh and not self.supports_mesh:
            reasons.append("cannot run under the vocab-parallel shard_map "
                           "combine")
        if (self.owns_reduction and req.reduction is not None
                and req.reduction != "mean"):
            reasons.append("computes grads in the forward and owns the "
                           "reduction, so only reduction='mean' is "
                           "expressible (the paper's composability "
                           "caveat, §2)")
        return reasons

    def satisfies(self, req: Requirements) -> bool:
        return not self.unsupported(req)

    def capabilities(self) -> dict:
        return {
            "memory_class": self.memory_class,
            "sum_logits": self.supports_sum_logits,
            "custom_cotangents": self.supports_custom_cotangents,
            "owns_reduction": self.owns_reduction,
            "mesh": self.supports_mesh,
            "preferred_platforms": self.preferred_platforms,
        }

    def _cannot(self, req: Requirements) -> str:
        able = [b.name for b in all_backends() if b.satisfies(req)]
        reasons = "; ".join(self.unsupported(req)) or "unknown requirement"
        return (f"backend {self.name!r} cannot satisfy this call: {reasons}."
                f" Backends that can: {', '.join(able) or '(none)'}")


# ---------------------------------------------------------------------------
# Lookup / resolution.
# ---------------------------------------------------------------------------

def list_backends() -> list:
    """Registered backend names, sorted."""
    return sorted(_REGISTRY)


def all_backends() -> list:
    """Registered backend singletons, sorted by name."""
    return [_REGISTRY[n] for n in list_backends()]


def get(name: str) -> Backend:
    """The registered backend singleton ``name`` (no capability check)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise BackendResolutionError(
            f"unknown backend {name!r}; registered: "
            f"{', '.join(list_backends())}") from None


def resolve(impl: str = "auto", *,
            requirements: Requirements = Requirements()) -> Backend:
    """The single dispatch point: name (or "auto") -> :class:`Backend`.

    A named ``impl`` is validated against ``requirements``; ``"auto"``
    picks the highest-priority satisfying backend that prefers the current
    platform (falling back to any satisfying backend). Errors enumerate
    the registered backends that *do* satisfy the requirements.
    """
    if impl != "auto":
        be = get(impl)
        if not be.satisfies(requirements):
            raise BackendResolutionError(be._cannot(requirements))
        return be

    candidates = [b for b in all_backends() if b.satisfies(requirements)]
    if not candidates:
        detail = "; ".join(
            f"{b.name}: {', '.join(b.unsupported(requirements))}"
            for b in all_backends())
        raise BackendResolutionError(
            f"no registered backend satisfies {requirements} ({detail})")
    platform = jax.default_backend()
    preferred = [b for b in candidates if platform in b.preferred_platforms]
    return max(preferred or candidates, key=lambda b: b.priority)


def resolve_config(cfg: Optional[CCEConfig], softcap=None) -> CCEConfig:
    """Canonical (cfg, softcap) merge shared by every entry point."""
    if cfg is None:
        return CCEConfig(softcap=softcap)
    if softcap is not None and cfg.softcap != softcap:
        return dataclasses.replace(cfg, softcap=softcap)
    return cfg


def capability_matrix() -> list:
    """[(name, capabilities dict)] for docs/benchmarks/tests."""
    return [(b.name, b.capabilities()) for b in all_backends()]
