"""The registered backends — one class per realization of the primitive.

Each mirrors a row of the paper's Table 1 (plus the scan twin); the class
attributes ARE the capability matrix rendered in the README. New backends
(e.g. a GPU Triton port, a ragged/paged variant) register here and every
caller of :func:`repro.backends.resolve` can use them immediately.
"""

from __future__ import annotations

from repro.backends.base import Backend, register
from repro.core import baselines, cce_jax
from repro.kernels import ops as kernel_ops


@register("cce")
class PallasCCE(Backend):
    """The paper's method: fused Pallas TPU kernels (interpret mode on
    CPU), gradient filtering + vocab sorting, custom VJP over arbitrary
    cotangents. The backward defaults to the single-pass fused kernel with
    forward-emitted block-sparsity maps (``CCEConfig.bwd`` /
    ``filter_stats`` — DESIGN.md §7); ``bwd="two_pass"`` restores the
    classic dE-then-dC pair (required for the Kahan/bf16 accumulator
    ablations)."""
    description = "Pallas TPU kernels (paper's CCE; interpret on CPU)"
    memory_class = "O(N·D + V·D)"
    supports_custom_cotangents = True
    supports_sum_logits = True
    supports_mesh = True
    preferred_platforms = ("tpu",)
    priority = 100
    shard_map_check_vma = False

    def lse_pick(self, E, C, x, cfg, *, with_sum_logits=False):
        if with_sum_logits:
            return kernel_ops.lse_pick_sum_pallas(E, C, x, cfg)
        return kernel_ops.lse_and_pick_pallas(E, C, x, cfg)

    def nll(self, E, C, x, cfg, *, num_chunks=8):
        return kernel_ops.linear_cross_entropy_pallas(E, C, x, cfg)


@register("cce_jax")
class ScanCCE(Backend):
    """Portable ``lax.scan`` twin — same algorithm and memory class,
    analyzable HLO; what the distributed train step lowers on the
    dry-run."""
    description = "portable lax.scan twin of the CCE kernels"
    memory_class = "O(N·D + V·D)"
    supports_custom_cotangents = True
    supports_sum_logits = True
    supports_mesh = True
    preferred_platforms = ("cpu", "gpu", "tpu")
    priority = 90

    def lse_pick(self, E, C, x, cfg, *, with_sum_logits=False):
        if with_sum_logits:
            return cce_jax.lse_pick_sum_jax(E, C, x, cfg)
        return cce_jax.lse_and_pick_jax(E, C, x, cfg)

    def nll(self, E, C, x, cfg, *, num_chunks=8):
        return cce_jax.linear_cross_entropy_jax(E, C, x, cfg)


@register("dense")
class DenseBaseline(Backend):
    """Paper "Baseline"/"torch.compile" row: the (N, V) logit matrix is
    materialized; plain autodiff provides the custom-cotangent primitive,
    making this the O(N·V) reference twin the tests gradcheck against."""
    description = "materialized-logits baseline (reference twin)"
    memory_class = "O(N·V)"
    supports_custom_cotangents = True
    supports_sum_logits = True
    supports_mesh = True   # Megatron-style vocab-parallel CE per shard
    preferred_platforms = ()
    priority = 10

    def lse_pick(self, E, C, x, cfg, *, with_sum_logits=False):
        return baselines.dense_lse_pick(E, C, x, cfg.softcap,
                                        with_sum=with_sum_logits)

    def nll(self, E, C, x, cfg, *, num_chunks=8):
        return baselines.dense_linear_cross_entropy(E, C, x, cfg.softcap)


@register("chunked")
class ChunkedBaseline(Backend):
    """Paper "Torch Tune (8 chunks)" row: token-chunked dense loss under
    ``jax.checkpoint``. Plain-NLL only — no primitive outputs."""
    description = "Torch-Tune-style N-chunked dense loss"
    memory_class = "O(N/K·V)"
    preferred_platforms = ()
    priority = 5

    def nll(self, E, C, x, cfg, *, num_chunks=8):
        return baselines.chunked_linear_cross_entropy(
            E, C, x, cfg.softcap, num_chunks)


@register("liger")
class LigerBaseline(Backend):
    """Paper "Liger Kernels" row: gradients computed during the forward
    and stored, so the op owns the (mean) reduction — the composability
    restriction the registry losses avoid."""
    description = "Liger-style forward-computed grads, scalar mean loss"
    memory_class = "O(N·D + V·D)"
    owns_reduction = True
    preferred_platforms = ()
    priority = 1

    def reduced_loss(self, E, C, x, cfg, *, num_chunks=8):
        return baselines.liger_style_cross_entropy(
            E, C, x, cfg.softcap, num_chunks)
