"""repro.backends — registry of CCE-primitive realizations.

    from repro import backends
    be = backends.resolve("auto", requirements=backends.Requirements(
        custom_cotangents=True, sum_logits=True))
    lse, pick, zsum = be.lse_pick(E, C, x, cfg, with_sum_logits=True)

Every impl the repo knows (Pallas ``cce``, scan ``cce_jax``, paper
baselines ``dense``/``chunked``/``liger``) is a registered
:class:`Backend` declaring its capabilities; :func:`resolve` replaces the
string if/elif chains that used to live at every call site, and
``python -m repro.backends`` prints the capability matrix.
"""

from repro.backends.base import (  # noqa: F401
    Backend,
    BackendResolutionError,
    Requirements,
    all_backends,
    capability_matrix,
    get,
    list_backends,
    register,
    resolve,
    resolve_config,
)
from repro.backends import entries as _entries  # noqa: F401  (populates)
from repro.backends.entries import (  # noqa: F401
    ChunkedBaseline,
    DenseBaseline,
    LigerBaseline,
    PallasCCE,
    ScanCCE,
)
