"""Print the backend capability matrix: ``python -m repro.backends``."""

from repro import backends


def main():
    cols = ("memory_class", "sum_logits", "custom_cotangents",
            "owns_reduction", "mesh", "preferred_platforms")
    rows = [(name, caps) for name, caps in backends.capability_matrix()]
    print(f"{'backend':10s} " + " ".join(f"{c:18s}" for c in cols))
    for name, caps in rows:
        cells = []
        for c in cols:
            v = caps[c]
            if isinstance(v, tuple):
                v = ",".join(v) or "-"
            cells.append(f"{str(v):18s}")
        print(f"{name:10s} " + " ".join(cells))


if __name__ == "__main__":
    main()
