"""Optimizer substrate: AdamW + schedules, pure pytree ops."""
from repro.optim.adamw import (  # noqa: F401
    adamw_init, adamw_update, clip_by_global_norm, global_norm,
    warmup_cosine,
)
