"""AdamW (decoupled weight decay) + global-norm clipping, pure pytree ops.

Optimizer moments are f32 regardless of parameter dtype (mixed-precision
training keeps bf16 params with f32 master statistics). State shards
identically to the parameters (the FSDP/ZeRO axis), so no extra sharding
rules are needed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, grad_clip=None):
    """Returns (new_params, new_state, metrics)."""
    if grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = global_norm(grads)

    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return m, v, new_p.astype(p.dtype)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_state = {
        "m": tdef.unflatten([o[0] for o in out]),
        "v": tdef.unflatten([o[1] for o in out]),
        "count": count,
    }
    new_params = tdef.unflatten([o[2] for o in out])
    return new_params, new_state, {"grad_norm": gnorm}


def warmup_cosine(step, *, base_lr, warmup_steps, total_steps,
                  final_frac=0.1):
    """Linear warmup then cosine decay to final_frac * base_lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    progress = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
        jnp.pi * progress))
    return jnp.where(step < warmup_steps, warm, base_lr * cos)
