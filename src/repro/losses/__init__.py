"""repro.losses — memory-efficient vocabulary losses on the CCE primitive.

    from repro.losses import get_loss
    loss = get_loss("label_smoothing", eps=0.1)
    per_token = loss(E, C, x, impl="cce")          # O(N·D + V·D) memory
    scalar    = loss(E, C, x, reduction="mean")

Registered losses (see ``repro/losses/zoo.py``): nll, z_loss, focal,
weighted, label_smoothing, seq_logprob. All lower onto
``repro.core.lse_and_pick`` and therefore never materialize the N×V logit
matrix under ``impl in ("cce", "cce_jax")``; ``impl="dense"`` is the
materialized reference twin used by the tests.
"""

from repro.losses.base import (  # noqa: F401
    LossConfig,
    VocabLoss,
    get_loss,
    list_losses,
    register,
)
from repro.losses import zoo as _zoo  # noqa: F401  (populates the registry)
from repro.losses.zoo import (  # noqa: F401
    NLL,
    FocalCE,
    LabelSmoothingCE,
    SequenceLogProb,
    WeightedCE,
    ZLoss,
)
