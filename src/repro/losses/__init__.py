"""repro.losses — memory-efficient vocabulary losses on the CCE primitive.

    from repro.losses import get_loss
    loss = get_loss("label_smoothing", eps=0.1)
    per_token = loss(E, C, x, impl="cce")          # O(N·D + V·D) memory
    scalar    = loss(E, C, x, reduction="mean")
    sharded   = loss(E, C, x, mesh=mesh)           # vocab-parallel combine

or, equivalently, through the one public entry point:

    from repro.core import cross_entropy
    cross_entropy(E, C, x, loss="label_smoothing", impl="auto", mesh=None)

Registered losses (see ``repro/losses/zoo.py``): nll, z_loss, focal,
weighted, label_smoothing, seq_logprob. All lower onto the ``lse_pick``
primitive of a :mod:`repro.backends` entry (resolved by capability) and
therefore never materialize the N×V logit matrix under the CCE-class
backends; ``impl="dense"`` is the materialized reference twin used by the
tests.
"""

from repro.losses.base import (  # noqa: F401
    LossConfig,
    VocabLoss,
    get_loss,
    list_losses,
    reduce_loss,
    register,
)
from repro.losses import zoo as _zoo  # noqa: F401  (populates the registry)
from repro.losses.zoo import (  # noqa: F401
    NLL,
    FocalCE,
    LabelSmoothingCE,
    SequenceLogProb,
    WeightedCE,
    ZLoss,
)
