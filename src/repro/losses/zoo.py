"""The loss zoo: every registered vocabulary loss.

Each entry is a frozen dataclass over its hyper-parameters; per-token math
is a closed-form function of the CCE primitive's ``(lse, pick[, sum])``
outputs, so every loss here runs in the O(N·D + V·D) memory class under
``impl in ("cce", "cce_jax")`` — verified per entry by
``benchmarks/loss_zoo_memory.py`` and gradchecked against the dense
materialized-logits twin in ``tests/test_losses.py``.

Useful identities (p_i = softmax probability of the label):

    nll_i    = lse_i - pick_i
    log p_i  = pick_i - lse_i          =>  p_i = exp(pick_i - lse_i)
    mean_z_i = sum_logits_i / V
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import backends
from repro.kernels.ref import IGNORE_INDEX
from repro.losses.base import (VocabLoss, primitive_outputs, reduce_loss,
                               register)


@register("nll")
@dataclasses.dataclass(frozen=True)
class NLL(VocabLoss):
    """Plain next-token cross-entropy: ``lse - pick`` (the paper's loss)."""

    def per_token(self, lse, pick, sum_logits, vocab):
        return lse - pick


@register("z_loss")
@dataclasses.dataclass(frozen=True)
class ZLoss(VocabLoss):
    """NLL + ``z_weight * lse**2`` (PaLM/Chronicals-style logit-norm
    regularizer). Purely cotangent-level: autodiff feeds the extra
    ``2*z_weight*lse`` cotangent into the primitive's custom VJP — no new
    kernel outputs, memory class unchanged."""
    z_weight: float = 1e-4

    def per_token(self, lse, pick, sum_logits, vocab):
        return (lse - pick) + self.z_weight * lse * lse


@register("focal")
@dataclasses.dataclass(frozen=True)
class FocalCE(VocabLoss):
    """Focal / confidence-weighted CE: ``(1 - p)**gamma * nll`` with
    ``p = exp(pick - lse)``. Down-weights already-confident tokens.

    ``detach_weight=True`` stops gradient through the ``(1-p)**gamma``
    factor (pure reweighting); False is the full focal-loss gradient.
    """
    gamma: float = 2.0
    detach_weight: bool = False

    def per_token(self, lse, pick, sum_logits, vocab):
        # clamp log p to <= 0: lse is computed by a separate (online)
        # reduction and can round one ulp below pick, and a fractional
        # gamma would turn the resulting negative 1-p into NaN.
        p = jnp.exp(jnp.minimum(pick - lse, 0.0))
        w = (1.0 - p) ** self.gamma
        if self.detach_weight:
            w = jax.lax.stop_gradient(w)
        return w * (lse - pick)


@register("weighted")
@dataclasses.dataclass(frozen=True)
class WeightedCE(VocabLoss):
    """Per-token weighted CE — e.g. completion-only fine-tuning masks or
    curriculum weights, passed as ``weights=`` at call time (shape of x).
    ``reduction="mean"`` normalizes by the weight sum, so a 0/1 completion
    mask yields the mean NLL over completion tokens only."""

    def per_token(self, lse, pick, sum_logits, vocab):
        # weighting itself is applied uniformly by VocabLoss.__call__;
        # the entry exists so the pattern is discoverable by name.
        return lse - pick


@register("label_smoothing")
@dataclasses.dataclass(frozen=True)
class LabelSmoothingCE(VocabLoss):
    """CE against the ε-smoothed target ``(1-ε)·onehot + ε·uniform``:

        L = (1-ε)·(lse - pick) + ε·(lse - sum_logits / V)

    The uniform term needs the mean logit — the primitive's third output —
    so this is the loss that exercises ``sum_logits`` end-to-end (and the
    reason gradient filtering is off in its backward: the uniform-target
    gradient is dense over the vocabulary).
    """
    eps: float = 0.1
    needs_sum_logits = True

    def per_token(self, lse, pick, sum_logits, vocab):
        smooth = lse - sum_logits / vocab
        return (1.0 - self.eps) * (lse - pick) + self.eps * smooth


@register("seq_logprob")
@dataclasses.dataclass(frozen=True)
class SequenceLogProb(VocabLoss):
    """Sequence log-probability scoring (eval/serve, not a training loss):
    ``log p(sequence) = sum_t (pick_t - lse_t)`` over non-ignored tokens.

    ``x`` of shape (B, S) yields one score per sequence; a 1-D ``x`` is one
    sequence. ``normalize="tokens"`` returns per-token average log-prob
    (length-normalized rescoring); "sum" the raw log-prob. ``reduction``
    then applies over *sequences*.
    """
    normalize: str = "sum"            # "sum" | "tokens"
    trainable = False

    def per_token(self, lse, pick, sum_logits, vocab):
        return pick - lse             # per-token log-prob

    def __call__(self, E, C, x, *, impl: str = "auto", backend=None,
                 softcap: float | None = None, cfg=None,
                 reduction: str = "none", weights=None, mesh=None,
                 vocab_axis: str = "model", token_axes=("data",)):
        cfg = self._resolve_cfg(cfg, softcap)
        be = backend if backend is not None else backends.resolve(
            impl, requirements=self.requirements(mesh=mesh,
                                                 reduction=reduction))
        lse, pick = primitive_outputs(be, E, C, x, cfg, mesh=mesh,
                                      vocab_axis=vocab_axis,
                                      token_axes=token_axes)
        logp = pick - lse
        if weights is not None:
            logp = logp * weights
        valid = x != IGNORE_INDEX
        logp = jnp.where(valid, logp, 0.0)
        tok_axis = tuple(range(1, logp.ndim)) or (0,)
        score = jnp.sum(logp, axis=tok_axis)
        if self.normalize == "tokens":
            n = jnp.maximum(jnp.sum(valid, axis=tok_axis), 1)
            score = score / n
        elif self.normalize != "sum":
            raise ValueError(f"normalize must be 'sum'|'tokens', "
                             f"got {self.normalize!r}")
        if reduction == "none":
            return score
        # reduce over sequences; scores have no IGNORE semantics of their own
        dummy = jnp.zeros(score.shape, jnp.int32)
        return reduce_loss(score, dummy, reduction)
