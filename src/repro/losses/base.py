"""Loss-family substrate: every vocabulary loss as a function of the CCE
``(lse, pick[, sum_logits])`` primitive.

The paper's real contribution is not one loss but a primitive: per-token
``lse`` and ``pick`` computed without materializing the N×V logit matrix,
with a custom VJP that accepts *arbitrary* cotangents. Any scalar-per-token
loss expressible through

    lse_i         = logsumexp_v softcap(C_v . E_i)
    pick_i        = softcap(C[x_i] . E_i)
    sum_logits_i  = sum_v softcap(C_v . E_i)          (optional 3rd output)

therefore inherits CCE's O(N·D + V·D) memory class for free — the backward
recomputes logit tiles in VMEM/registers exactly as for plain NLL.
:class:`VocabLoss` packages that recipe; concrete losses only implement
:meth:`VocabLoss.per_token` on the primitive's outputs.

Which *realization* computes the primitive is a :mod:`repro.backends`
entry (resolved by capability, never by string chains here), and passing
``mesh=`` routes the same backend through the vocab-parallel shard_map
combine — so every registry loss runs sharded or local through one path.

Registry: losses register under a string name (``@register("z_loss")``);
``get_loss(name, **kwargs)`` instantiates a configured loss, and
:class:`LossConfig` is the hashable config-file/CLI carrier of the same
information.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp

from repro import backends
from repro.kernels.ops import CCEConfig
from repro.kernels.ref import IGNORE_INDEX

_REGISTRY: Dict[str, type] = {}


def register(name: str) -> Callable[[type], type]:
    """Class decorator: add a :class:`VocabLoss` subclass to the registry."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_loss(name: str, **kwargs):
    """Instantiate the registered loss ``name`` with its hyper-parameters.

    >>> loss = get_loss("z_loss", z_weight=1e-4)
    >>> per_token = loss(E, C, x, impl="cce_jax")
    """
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown loss {name!r}; registered: {', '.join(list_losses())}")
    return cls(**kwargs)


def list_losses() -> list:
    """Registered loss names, sorted."""
    return sorted(_REGISTRY)


@dataclasses.dataclass(frozen=True)
class LossConfig:
    """Hashable (name, kwargs) carrier for configs/CLIs.

    ``kwargs`` is a sorted tuple of (key, value) pairs so the config can be
    a static jit argument; ``build()`` turns it into the live loss object.
    """
    name: str = "nll"
    kwargs: tuple = ()

    @classmethod
    def create(cls, name: str, **kwargs) -> "LossConfig":
        return cls(name=name, kwargs=tuple(sorted(kwargs.items())))

    @classmethod
    def from_json(cls, name: str, json_kwargs: str) -> "LossConfig":
        """CLI entry point: parse '{"eps": 0.1}'-style hyper-parameters
        with errors a user can act on (both CLIs share this path)."""
        import json
        try:
            kwargs = json.loads(json_kwargs or "{}")
        except json.JSONDecodeError as e:
            raise SystemExit(
                f"--loss-kwargs must be a JSON object, e.g. "
                f"'{{\"eps\": 0.1}}'; got {json_kwargs!r} ({e})")
        if not isinstance(kwargs, dict):
            raise SystemExit(
                f"--loss-kwargs must be a JSON *object*, got "
                f"{type(kwargs).__name__}: {json_kwargs!r}")
        return cls.create(name, **kwargs)

    def build(self):
        return get_loss(self.name, **dict(self.kwargs))


def reduce_loss(per_token, x, reduction: str, weights=None):
    """The canonical reduction — "none" | "sum" | "mean" — shared by every
    entry point (``repro.core`` used to carry a near-twin ``_reduce``).

    Mean is over non-ignored tokens; with ``weights`` it is
    weight-normalized (sum w·l / sum w over valid tokens — the
    completion-only fine-tuning convention). One denominator semantics for
    both cases: a small floor (1e-8) that only engages when *nothing* is
    valid, in which case the numerator is already 0 and the mean is 0.
    """
    if reduction == "none":
        return per_token
    valid = x != IGNORE_INDEX
    total = jnp.sum(per_token)
    if reduction == "sum":
        return total
    if reduction == "mean":
        if weights is not None:
            denom = jnp.sum(jnp.where(valid, weights, 0.0))
        else:
            denom = jnp.sum(valid)
        return total / jnp.maximum(denom, 1e-8).astype(per_token.dtype)
    raise ValueError(f"unknown reduction {reduction!r}")


def primitive_outputs(backend, E, C, x, cfg: CCEConfig, *,
                      with_sum_logits: bool = False, mesh=None,
                      vocab_axis: str = "model", token_axes=("data",)):
    """(lse, pick[, sum_logits]) tuple from ``backend`` — locally, or under
    the vocab-parallel shard_map combine when ``mesh`` is given. The one
    junction where "distributed" becomes a property of the call."""
    if mesh is None:
        return backend.lse_pick(E, C, x, cfg,
                                with_sum_logits=with_sum_logits)
    # lazy: repro.core.vocab_parallel triggers repro.core.__init__
    from repro.core import vocab_parallel as vp
    orig_shape = x.shape
    if E.ndim > 2:
        E = E.reshape(-1, E.shape[-1])
        x = x.reshape(-1)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x).astype(jnp.int32)
    outs = vp.vocab_parallel_lse_pick(
        E, C, safe_x, mesh=mesh, vocab_axis=vocab_axis,
        token_axes=token_axes, backend=backend, cfg=cfg,
        with_sum_logits=with_sum_logits)
    return tuple(o.reshape(orig_shape) for o in outs)


@dataclasses.dataclass(frozen=True)
class VocabLoss:
    """Base class: a per-token vocabulary loss lowered onto the CCE
    primitive.

    Subclasses set ``needs_sum_logits`` when they use the third output and
    implement :meth:`per_token`. ``__call__`` resolves a
    :mod:`repro.backends` entry by capability (or takes a pre-resolved
    ``backend=``), routes through the vocab-parallel combine when
    ``mesh=`` is given, and handles IGNORE_INDEX masking, optional
    per-token ``weights``, and the reduction.
    """
    needs_sum_logits = False   # class attribute, overridden by subclasses
    trainable = True

    def per_token(self, lse, pick, sum_logits, vocab: int):
        raise NotImplementedError

    def __call__(self, E, C, x, *, impl: str = "auto", backend=None,
                 softcap: float | None = None,
                 cfg: CCEConfig | None = None,
                 reduction: str = "none",
                 weights=None, mesh=None, vocab_axis: str = "model",
                 token_axes=("data",)):
        cfg = backends.resolve_config(cfg, softcap)
        be = backend if backend is not None else backends.resolve(
            impl, requirements=self.requirements(mesh=mesh,
                                                 reduction=reduction))
        outs = primitive_outputs(be, E, C, x, cfg,
                                 with_sum_logits=self.needs_sum_logits,
                                 mesh=mesh, vocab_axis=vocab_axis,
                                 token_axes=token_axes)
        lse, pick = outs[0], outs[1]
        sum_logits = outs[2] if self.needs_sum_logits else None
        per_tok = self.per_token(lse, pick, sum_logits, C.shape[0])
        if weights is not None:
            per_tok = per_tok * weights
        per_tok = jnp.where(x == IGNORE_INDEX, 0.0, per_tok)
        return reduce_loss(per_tok, x, reduction, weights)

    def requirements(self, *, mesh=None,
                     reduction: str = "none") -> backends.Requirements:
        """What this loss needs from a backend (capability resolution)."""
        return backends.Requirements(
            custom_cotangents=True,
            sum_logits=self.needs_sum_logits,
            mesh=mesh is not None,
            reduction=reduction)

    @staticmethod
    def _resolve_cfg(cfg, softcap):
        return backends.resolve_config(cfg, softcap)
