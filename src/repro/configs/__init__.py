"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

One module per assigned architecture (exact configs from the assignment
table) plus the paper's own Gemma-2 2B. Each module defines ``CONFIG`` and
``reduced()`` (a small same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCHS = (
    "seamless_m4t_medium",
    "starcoder2_7b",
    "llama3_2_3b",
    "h2o_danube3_4b",
    "gemma_2b",
    "qwen2_vl_7b",
    "recurrentgemma_9b",
    "olmoe_1b_7b",
    "qwen2_moe_a2_7b",
    "rwkv6_3b",
    # the paper's flagship model (benchmarks, not part of the 40 cells)
    "gemma2_2b",
)

_ALIASES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "starcoder2-7b": "starcoder2_7b",
    "llama3.2-3b": "llama3_2_3b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "gemma-2b": "gemma_2b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "rwkv6-3b": "rwkv6_3b",
    "gemma2-2b": "gemma2_2b",
}

ASSIGNED = ARCHS[:10]


def _module(arch: str):
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; one of {ARCHS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_reduced_config(arch: str):
    return _module(arch).reduced()
