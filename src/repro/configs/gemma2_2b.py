"""gemma2-2b — the paper's flagship benchmark model (Table 1): 26L, d=2304,
8H (GQA kv=4), head_dim 256, ff=9216, |V|=256128, logit softcap 30
[arXiv:2408.00118]. Not one of the 40 assigned cells; used by the paper
benchmarks (benchmarks/table1_loss_memory.py uses N=8192, D=2304,
|V|=256000 to match the paper exactly)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256128,
    layer_pattern=("attn", "swa"),
    sliding_window=4096,
    mlp_activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    logit_softcap=30.0,
    attn_softcap=50.0,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=512, sliding_window=32)
