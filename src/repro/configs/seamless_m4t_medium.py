"""seamless-m4t-medium [audio]: enc-dec, 12+12L, d=1024, 16H (kv=16),
ff=4096, |V|=256206 [arXiv:2308.11596; hf].

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings for the encoder; the decoder is a text LM whose
256k-vocab head is the biggest CCE win per parameter in the pool.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    layer_pattern=("attn",),
    mlp_activation="gelu",
    rope_theta=10000.0,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=512)
