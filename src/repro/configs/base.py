"""Config dataclasses: model architecture, input shapes, mesh, training.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``
registered under its ``--arch`` id. Shapes are the four assigned input-shape
cells; meshes are the production single-/multi-pod meshes (launch/mesh.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int            # per-expert FFN hidden size
    num_shared_experts: int = 0  # qwen2-moe: always-active shared experts
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01  # load-balance loss weight
    dispatch: str = "gather"     # "gather" (sort/scatter) | "einsum" (one-hot)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """RG-LRU (recurrentgemma) / RWKV-6 temporal-mixer hyper-params."""
    kind: str                    # "rglru" | "rwkv6"
    chunk_len: int = 64          # chunked-recurrence length (rwkv6)
    conv_width: int = 4          # temporal conv (rglru recurrent block)
    lru_width: Optional[int] = None  # rglru recurrence width (default d_model)
    head_dim: int = 64           # rwkv6 head size
    decay_lora: int = 64         # rwkv6 data-dependent decay LoRA rank


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default d_model // num_heads
    # layer pattern: mixer kinds cycled over layers.
    #   "attn"   full causal self-attention
    #   "swa"    sliding-window attention (window = sliding_window)
    #   "rglru"  RG-LRU recurrent block (recurrentgemma)
    #   "rwkv6"  RWKV-6 linear-attention mixer
    layer_pattern: tuple = ("attn",)
    sliding_window: Optional[int] = None
    mlp_activation: str = "silu"        # silu | geglu | gelu
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    rope_sections: Optional[tuple] = None  # qwen2-vl M-RoPE (t, h, w) split
    norm_eps: float = 1e-6
    embed_scale: bool = False           # gemma-style sqrt(d) embed scaling
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder_layers: int = 0             # >0 => encoder-decoder (seamless)
    input_mode: str = "tokens"          # tokens | embeds (vlm/audio stubs)
    dtype: str = "bfloat16"
    loss_impl: str = "cce_jax"          # repro.backends entry for the head
    remat: str = "block"                # none | block (checkpoint each group)
    # Megatron-style vocab padding: embed/head rows are padded to a multiple
    # of this so the classifier shards evenly over any TP degree dividing it
    # (and stays MXU-aligned). Labels never reference padded rows; training
    # pushes their probability down exactly as in Megatron-LM.
    vocab_pad_multiple: int = 512
    # Gradient-accumulation microbatch (rows of the global batch per
    # accumulation step) for the production train step. Per-step roofline
    # totals are unchanged; peak activation transients shrink ~linearly —
    # set for archs whose full-batch train step exceeds the 16 GB/chip HBM.
    train_microbatch: Optional[int] = None

    @property
    def padded_vocab_size(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def pattern_for(self, num_layers: int) -> tuple:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(num_layers))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qkv = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads
        att = qkv + self.num_heads * hd * d
        mlp_mult = 3 if self.mlp_activation in ("silu", "geglu") else 2
        if self.moe is not None:
            moe = self.moe
            mlp = (moe.num_experts * mlp_mult * d * moe.d_ff_expert
                   + d * moe.num_experts)
            if moe.num_shared_experts:
                mlp += mlp_mult * d * moe.d_ff_expert * moe.num_shared_experts
        else:
            mlp = mlp_mult * d * ff
        per_layer = {"attn": att + mlp, "swa": att + mlp,
                     "rglru": 3 * d * d + mlp, "rwkv6": 4 * d * d + mlp}
        total = sum(per_layer[k] for k in self.pattern_for(self.num_layers))
        if self.is_encdec:
            total += self.encoder_layers * (att + mlp) + self.num_layers * att
        total += v * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        moe = self.moe
        mlp_mult = 3 if self.mlp_activation in ("silu", "geglu") else 2
        inactive = ((moe.num_experts - moe.top_k)
                    * mlp_mult * self.d_model * moe.d_ff_expert
                    * self.num_layers)
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatch: Optional[int] = None    # grad-accumulation microbatch size
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    seed: int = 0
    grad_allreduce_dtype: Optional[str] = None  # e.g. "bfloat16" compression
    # Training loss from the repro.losses registry (nll, z_loss, focal,
    # weighted, label_smoothing, ...) with its hyper-parameters as sorted
    # (key, value) pairs — hashable, so TrainConfig stays a valid static
    # arg. Use loss_options() to read them back as a dict.
    loss: str = "nll"
    loss_kwargs: tuple = ()

    def loss_options(self) -> dict:
        return dict(self.loss_kwargs)

    def loss_config(self):
        """The same information as a ``repro.losses.LossConfig`` — the
        carrier ``repro.core.cross_entropy(loss=...)`` accepts directly."""
        from repro.losses import LossConfig
        return LossConfig(name=self.loss, kwargs=self.loss_kwargs)
