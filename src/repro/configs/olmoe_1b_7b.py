"""olmoe-1b-7b [moe]: 16L, d=2048, 16H (kv=16), expert ff=1024, |V|=50304,
MoE 64 experts top-8 [arXiv:2409.02060; hf]."""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    layer_pattern=("attn",),
    mlp_activation="silu",
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    # full-batch train step exceeds 16 GB/chip; 4-step grad accumulation
    train_microbatch=64,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96))
