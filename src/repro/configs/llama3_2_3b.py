"""llama3.2-3b [dense]: 28L, d=3072, 24H (GQA kv=8), ff=8192, |V|=128256
[hf:meta-llama/Llama-3.2-1B; unverified]."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    layer_pattern=("attn",),
    mlp_activation="silu",
    rope_theta=5e5,
    tie_embeddings=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512)
