"""h2o-danube-3-4b [dense]: 24L, d=3840, 32H (GQA kv=8), ff=10240,
|V|=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified]. Window 4096 (mistral-style).

SWA gives this arch a bounded decode cache, so long_500k runs (ring
buffer), despite being otherwise a dense transformer.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    layer_pattern=("swa",),
    sliding_window=4096,
    mlp_activation="silu",
    rope_theta=10000.0,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512, sliding_window=32)
