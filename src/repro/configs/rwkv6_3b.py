"""rwkv6-3b [ssm] "Finch": 32L, d=2560, attention-free, ff=8960, |V|=65536
— data-dependent per-channel decay [arXiv:2404.05892; hf].

head_dim 64 (40 heads). O(1) state => long_500k decode runs. The head is a
standard linear classifier, so CCE applies verbatim (DESIGN.md §6).
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # informational: rwkv6 heads = d_model / head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    layer_pattern=("rwkv6",),
    mlp_activation="silu",  # unused by rwkv6 channel mix
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk_len=128, decay_lora=64),
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        ssm=SSMConfig(kind="rwkv6", head_dim=16, chunk_len=16, decay_lora=8))
