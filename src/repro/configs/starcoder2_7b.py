"""starcoder2-7b [dense]: 32L, d=4608, 36H (GQA kv=4), ff=18432,
|V|=49152 — GQA + RoPE [arXiv:2402.19173; hf]."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    layer_pattern=("attn",),
    mlp_activation="gelu",
    rope_theta=1e5,
    # full-batch train step exceeds 16 GB/chip; 2-step grad accumulation
    train_microbatch=128,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=72, num_heads=6, num_kv_heads=2,
        d_ff=144, vocab_size=512)
