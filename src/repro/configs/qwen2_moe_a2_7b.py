"""qwen2-moe-a2.7b [moe]: 24L, d=2048, 16H (kv=16), expert ff=1408,
|V|=151936 — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    layer_pattern=("attn",),
    mlp_activation="silu",
    rope_theta=1e6,
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  num_shared_experts=4),
    # full-batch train step exceeds 16 GB/chip; 4-step grad accumulation
    train_microbatch=64,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=512,
        moe=MoEConfig(num_experts=6, top_k=2, d_ff_expert=96,
                      num_shared_experts=2))
