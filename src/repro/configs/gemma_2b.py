"""gemma-2b [dense]: 18L, d=2048, 8H (MQA kv=1), ff=16384, |V|=256000 —
GeGLU, head_dim=256 [arXiv:2403.08295; hf]. Tied embeddings + sqrt(d)
embed scaling (gemma family)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    layer_pattern=("attn",),
    mlp_activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
    # remat="save_dots" was tried and REFUTED for this memory-bound cell
    # (§Perf gemma G2): compute -13% but the dominant memory term +16%
    # and per-device bytes 9.0 -> 20.4 GB (over the 16 GB HBM budget).
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=256, vocab_size=512)
