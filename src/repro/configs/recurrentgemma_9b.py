"""recurrentgemma-9b [hybrid]: 38L, d=4096, 16H (MQA kv=1), ff=12288,
|V|=256000 — RG-LRU + local attention, 2 recurrent : 1 attention
[arXiv:2402.19427; unverified]. Local attention window 2048.

38 = 12 x (rglru, rglru, swa) + 2 rglru tail layers. O(1) recurrent state
and a window-bounded attention cache => long_500k decode runs.
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "swa"),
    sliding_window=2048,
    mlp_activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    ssm=SSMConfig(kind="rglru", conv_width=4),
    # full-batch train step exceeds 16 GB/chip; 4-step grad accumulation
    train_microbatch=64,
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=6, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=512, sliding_window=32,
        ssm=SSMConfig(kind="rglru", conv_width=4, lru_width=None))
