"""qwen2-vl-7b [vlm]: 28L, d=3584, 28H (GQA kv=4), ff=18944, |V|=152064 —
M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only per the assignment: the vision frontend is a STUB —
``input_specs`` provides precomputed patch/text embeddings plus the three
M-RoPE position streams (t, h, w). head_dim=128, sections (16, 24, 24)
half-dims.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    layer_pattern=("attn",),
    mlp_activation="silu",
    rope_theta=1e6,
    rope_sections=(16, 24, 24),
    input_mode="embeds",
)


def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512, rope_sections=(4, 2, 2))
