"""Parameter / cache / input PartitionSpec rules (FSDP x TP x EP + pod DP).

Mapping (DESIGN.md §5):
  * ``model`` axis: tensor parallel — attention heads, MLP ff, MoE experts
    (EP), the classifier vocab (the CCE axis), recurrence width.
  * ``data`` axis: FSDP/ZeRO-3 — the non-TP dim of every weight is sharded
    over data; XLA SPMD all-gathers per layer and reduce-scatters grads.
  * ``pod`` axis (multi-pod): pure DP replicas — parameters replicated,
    gradients all-reduced across pods.

Every rule degrades gracefully: an axis is applied only if it divides the
dimension (``_shard_if``), so MQA heads, odd head_dims etc. simply stay
replicated on that axis instead of failing to lower.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"
FSDP_AXIS = "data"


def _axsize(mesh, axis):
    if isinstance(axis, tuple):
        return int(np.prod([_axsize(mesh, a) for a in axis]))
    return mesh.shape[axis] if axis in mesh.axis_names else 0


def _shard_if(mesh, dim, axis):
    """axis if it exists in mesh and divides dim, else None."""
    size = _axsize(mesh, axis)
    return axis if size and dim % size == 0 else None


def _spec2(mesh, shape, a0, a1):
    return P(_shard_if(mesh, shape[0], a0), _shard_if(mesh, shape[1], a1))


def _param_rule(mesh, path_keys, shape, cfg):
    """Base spec (without the stacked-group axis) for one parameter leaf."""
    name = path_keys[-1]
    parent = path_keys[-2] if len(path_keys) > 1 else ""
    M, F = MODEL_AXIS, FSDP_AXIS

    if name == "embed":
        # tied embeddings double as the CCE classifier -> vocab-parallel
        return (_spec2(mesh, shape, M, None) if cfg.tie_embeddings
                else _spec2(mesh, shape, None, M))
    if name == "head":
        return _spec2(mesh, shape, M, None)   # vocab-parallel CCE classifier

    if name in ("wq", "wk", "wv"):
        return _spec2(mesh, shape, F, M)      # column parallel
    if name == "wo":
        return _spec2(mesh, shape, M, F)      # row parallel
    if name in ("w_up", "w_gate") and parent != "mixer":
        if len(shape) == 3:                   # MoE experts (E, d, ff)
            # TP inside each expert over the ff dim (column-parallel; the
            # gating nonlinearity is elementwise over ff so this is exact).
            # Chosen over EP-on-E: shape-robust for E that doesn't divide
            # the axis (qwen2-moe: 60/16) and pairs with the shard_map MoE
            # block (layers._routed_experts_sharded) whose only collectives
            # are the Megatron-SP all-gather/reduce-scatter of activations.
            return P(None, _shard_if(mesh, shape[1], F),
                     _shard_if(mesh, shape[2], M))
        return _spec2(mesh, shape, F, M)
    if name == "w_down":
        if len(shape) == 3:                   # MoE experts (E, ff, d)
            return P(None, _shard_if(mesh, shape[1], M),
                     _shard_if(mesh, shape[2], F))
        return _spec2(mesh, shape, M, F)
    if name == "router":
        return P(*([None] * len(shape)))      # tiny; replicate (read inside
                                              # the shard_map'd MoE block)
    if name == "shared_gate":
        return _spec2(mesh, shape, F, None)

    # rglru
    if name in ("w_x",):
        return _spec2(mesh, shape, F, M)
    if name == "w_out":
        return _spec2(mesh, shape, M, F)
    if name in ("w_a", "w_i"):
        return _spec2(mesh, shape, F, M)
    if name == "conv_w":
        return P(None, _shard_if(mesh, shape[1], M))
    if name == "lam":
        return P(_shard_if(mesh, shape[0], M))

    # rwkv6
    if name in ("w_r", "w_k", "w_v", "w_g"):
        if len(shape) == 2 and shape[0] == shape[1]:
            return _spec2(mesh, shape, F, M)
        return _spec2(mesh, shape, F, M)
    if name == "w_o":
        return _spec2(mesh, shape, M, F)
    if name == "decay_A":
        return _spec2(mesh, shape, F, None)
    if name == "decay_B":
        return _spec2(mesh, shape, None, M)
    if name in ("decay_w0",):
        return P(_shard_if(mesh, shape[0], M))
    if name in ("shift_mix", "mix"):
        return P(None, _shard_if(mesh, shape[1], M))

    # norms, scalars, small params: replicated
    return P(*([None] * len(shape)))


def _path_keys(path):
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(cfg, params, mesh):
    """Pytree of PartitionSpec matching ``params`` (shapes or arrays).

    Works for the raw parameter tree AND for trees wrapping it (optimizer
    moments {"m": params, "v": params}): stacked-block detection looks for
    the "blocks"/"cross" path component anywhere, not just at the root —
    a wrapper prefix must not silently demote stacked params to the
    (wrong, often fully-replicated) flat rules.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        keys = _path_keys(path)
        shape = leaf.shape
        stacked = "blocks" in keys or "cross" in keys
        base_shape = shape[1:] if stacked else shape
        spec = _param_rule(mesh, keys, base_shape, cfg)
        if stacked:
            spec = P(None, *spec)
        out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_specs(cfg, cache, mesh, data_axes):
    """Decode-cache specs: batch over data axes; KV head_dim over model
    (flash-decode style TP — the contraction over head_dim is what SPMD
    partitions); recurrent states batch-sharded, width over model."""
    dp = tuple(a for a in data_axes if a in mesh.axis_names)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        keys = _path_keys(path)
        name = keys[-1]
        stacked = keys[0] == "groups"   # leading n_groups axis
        shape = leaf.shape[1:] if stacked else leaf.shape
        if name in ("k_pages", "v_pages"):
            # (num_pages, page_size, hkv, hd) page pools: no batch axis
            # (pages are shared across rows), so only head_dim can shard
            spec = [None] * len(shape)
            spec[-1] = _shard_if(mesh, shape[-1], MODEL_AXIS)
        elif name == "pt":
            # (B, n_logical) page table: batch over data, replicated on
            # model (every TP shard gathers through the same table)
            spec = [_shard_if(mesh, shape[0], dp)] + \
                [None] * (len(shape) - 1)
        elif name == "pos":
            # (B, W) per-row ring positions: batch-sharded with their K/V
            spec = [_shard_if(mesh, shape[0], dp)] + \
                [None] * (len(shape) - 1)
        else:
            spec = [_shard_if(mesh, shape[0], dp)] + [None] * (len(shape) - 1)
            if len(shape) >= 2:
                spec[-1] = _shard_if(mesh, shape[-1], MODEL_AXIS)
        if stacked:
            spec = [None] + spec
        out.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
