"""Sharding: parameter PartitionSpec rules + activation constraints."""

from repro.sharding.constraints import (  # noqa: F401
    ShardingRules,
    constrain,
    make_rules,
    use_sharding_rules,
)
