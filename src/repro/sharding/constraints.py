"""Activation-sharding constraint injection.

Model code tags activations with semantic kinds (``constrain(x, "residual")``
etc.); the launcher installs a rule set mapping kinds to PartitionSpecs for
the active mesh. Without an installed rule set every tag is a no-op, so the
models stay mesh-agnostic and runnable on one device.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


class ShardingRules:
    """kind -> callable(ndim) -> PartitionSpec (or None to skip)."""

    def __init__(self, mesh, rules):
        self.mesh = mesh
        self.rules = rules

    def spec_for(self, kind, ndim):
        fn = self.rules.get(kind)
        return None if fn is None else fn(ndim)


@contextlib.contextmanager
def use_sharding_rules(rules: ShardingRules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def current_rules():
    """The installed ShardingRules (or None outside a launcher context).
    Lets mesh-aware blocks (sharded MoE dispatch) discover the mesh without
    threading it through every model signature."""
    return getattr(_state, "rules", None)


def constrain(x, kind: str):
    rules = getattr(_state, "rules", None)
    if rules is None:
        return x
    spec = rules.spec_for(kind, x.ndim)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def make_rules(mesh, *, data_axes=("data",), model_axis="model",
               seq_shard: bool = True):
    """Production rule set: batch over data axes; residual stream optionally
    sequence-sharded over the model axis (Megatron-SP style) so per-device
    activation checkpoints stay flat as TP grows."""
    dp = tuple(a for a in data_axes if a in mesh.axis_names)

    def residual(ndim):
        if ndim == 3:   # (B, S, D)
            return P(dp, model_axis if seq_shard else None, None)
        if ndim == 2:   # (N, D) flat tokens
            return P(dp, None)
        return None

    return ShardingRules(mesh, {"residual": residual})
