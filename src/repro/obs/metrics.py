"""Low-overhead metrics: counters, gauges, histograms behind one registry.

Design constraints (DESIGN.md §8):

  * **Zero-sync**: instruments only ever record values the host already
    holds — a metric call must never force a ``device_get``. The serve
    engine piggybacks all of its telemetry on the single per-step status
    sync it performs anyway; the trainer accumulates its one extra scalar
    device-side and materializes it only at log boundaries.
  * **Disabled is free**: the no-op twin (:data:`NULL`) implements the
    whole surface with empty methods, so instrumented code is written
    unconditionally (``self.metrics.counter(...)``) and a disabled engine
    runs the identical jitted computation — no recompiles, no branches in
    hot loops (asserted by tests/test_serve.py).
  * **Host-only**: pure Python floats/ints; nothing here imports JAX.

Instruments are memoized by ``(name, labels)``, so ``registry.counter("x")``
in a loop is a dict hit, not an allocation. Exposition formats live in
:mod:`repro.obs.prom` (Prometheus text) and :mod:`repro.obs.trace`
(JSONL snapshots).
"""

from __future__ import annotations

import bisect
import threading
import time

# Prometheus-style default buckets, extended down to 100us: serve steps at
# reduced-config sizes land in the 1-50ms range and TTFT in 10ms-2s.
DEFAULT_BUCKETS = (.0001, .00025, .0005, .001, .0025, .005, .01, .025,
                   .05, .1, .25, .5, 1.0, 2.5, 5.0, 10.0)


class Counter:
    """Monotonically increasing value (events, tokens, requests)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name, self.labels, self.value = name, labels, 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({amount}))")
        self.value += amount


class Gauge:
    """Last-written value (queue depth, occupancy, live-block fraction)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name, self.labels, self.value = name, labels, 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations <= its upper bound; +Inf is implicit via
    ``count``). ``observe`` is two list lookups and three adds."""

    __slots__ = ("name", "labels", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, labels: tuple = (),
                 buckets: tuple = DEFAULT_BUCKETS):
        self.name, self.labels = name, labels
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * len(self.buckets)
        self.sum, self.count = 0.0, 0

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.buckets, value)
        if i < len(self.counts):
            self.counts[i] += 1
        self.sum += value
        self.count += 1

    def cumulative(self) -> list:
        """[(upper_bound, cumulative_count)] in Prometheus ``le`` order."""
        out, acc = [], 0
        for ub, c in zip(self.buckets, self.counts):
            acc += c
            out.append((ub, acc))
        return out

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


def _label_key(labels: dict | None) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in (labels or {}).items()))


class Registry:
    """The metric namespace: memoizing factory + snapshot/exposition root.

    One registry per subsystem instance (an :class:`~repro.serve.engine.
    Engine`, a :class:`~repro.train.trainer.Trainer`) or one per process —
    both work; names are only required to be unique *within* a registry
    (same name + same labels returns the same instrument; same name with a
    different type raises).
    """

    enabled = True

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict | None, **kw):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, key[1], **kw)
                    self._metrics[key] = m
        if type(m) is not cls:
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, labels: dict | None = None) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: dict | None = None) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, labels: dict | None = None,
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def collect(self) -> list:
        """All instruments, sorted by (name, labels) — the stable order
        both exposition formats share."""
        return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self, ts: float | None = None) -> dict:
        """One JSON-ready record of every instrument's current value —
        the payload :class:`repro.obs.trace.JsonlSink` writes as a
        ``{"type": "metrics"}`` event."""
        out = {"type": "metrics",
               "ts": time.time() if ts is None else ts, "metrics": []}
        for m in self.collect():
            rec = {"name": m.name, "labels": dict(m.labels)}
            if isinstance(m, Histogram):
                rec.update(kind="histogram", sum=m.sum, count=m.count,
                           buckets=[[ub, c] for ub, c in m.cumulative()])
            else:
                rec.update(kind=type(m).__name__.lower(), value=m.value)
            out["metrics"].append(rec)
        return out

    def value(self, name: str, labels: dict | None = None) -> float:
        """Test/debug convenience: current value of a counter/gauge."""
        return self._metrics[(name, _label_key(labels))].value

    def total(self, name: str) -> float:
        """Sum of a counter/gauge across all of its label sets (0.0 when
        the name was never registered)."""
        return sum(m.value for (n, _), m in self._metrics.items()
                   if n == name)


class _NullInstrument:
    """One shared do-nothing instrument: every mutator is a no-op."""

    __slots__ = ()
    name, labels, value, sum, count, mean = "", (), 0.0, 0.0, 0, 0.0

    def inc(self, amount: float = 1.0) -> None: pass
    def dec(self, amount: float = 1.0) -> None: pass
    def set(self, value: float) -> None: pass
    def observe(self, value: float) -> None: pass
    def cumulative(self) -> list: return []


class NullRegistry(Registry):
    """The disabled path: hands out one shared no-op instrument, collects
    nothing. Instrumented code holds a registry unconditionally and pays a
    method call that does no work — never a branch, never an allocation."""

    enabled = False
    _NULL_INSTRUMENT = _NullInstrument()

    def __init__(self):
        super().__init__()

    def _get(self, cls, name, labels, **kw):
        return self._NULL_INSTRUMENT

    def collect(self) -> list:
        return []


#: Shared no-op registry; ``metrics or NULL`` is the canonical default.
NULL = NullRegistry()
