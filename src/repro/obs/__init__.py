"""repro.obs — flight-recorder observability: metrics + tracing.

The paper's whole pitch is a measured claim (24 GB -> 1 MB for the loss,
no throughput lost); this package makes the system answer "what is
tokens/s, TTFT, or the live-block fraction *right now*" without an ad-hoc
benchmark run. Three pieces (DESIGN.md §8):

  * :mod:`repro.obs.metrics` — counters/gauges/histograms behind a
    :class:`Registry`; the :data:`NULL` registry is the disabled path
    (no-op methods, zero recompiles, zero branches in hot loops).
  * :mod:`repro.obs.trace` — span-based tracing into a JSONL event sink
    (:class:`JsonlSink`); keyed spans cover lifecycles that cross frames
    (a serve request from admission to retirement).
  * :mod:`repro.obs.prom` — Prometheus text exposition + an optional
    ``/metrics`` scrape endpoint (stdlib-only).
  * :mod:`repro.obs.kernels` — the CCE observables the paper plots
    (live-block fraction, VMEM working set, per-backend memory class)
    recorded as gauges.

Instrumented layers: ``serve.engine``/``serve.scheduler`` (per-step
telemetry piggybacked on the engine's single host sync — metrics add zero
``device_get``s), ``train.trainer`` (structured step records), and the
kernel probes above. Hard invariant, asserted by tests/test_serve.py:
enabling metrics never adds a host sync or a jit recompile.
"""

from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    NULL,
    NullRegistry,
    Registry,
)
from repro.obs.prom import exposition, start_http_server  # noqa: F401
from repro.obs.trace import JsonlSink, Tracer, read_jsonl  # noqa: F401
