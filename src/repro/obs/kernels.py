"""Kernel observables as metrics: the quantities the paper plots, gauged.

  * ``cce_live_block_fraction`` — fraction of (n_block, v_block) tiles the
    CCE backward will visit, from the forward-emitted bitmap (DESIGN.md
    §7). This is paper Fig. 3's softmax sparsity surfaced as a *live
    training metric*: no softmax matrix is ever materialized.
  * ``cce_live_block_fraction_alg4`` — the exact paper-Alg.-4 statistic
    from the :func:`repro.kernels.ref.ref_block_live` oracle (opt-in:
    it materializes N×V, so probe sizes only — tests/validation).
  * ``cce_block_n`` / ``cce_block_v`` / ``cce_vmem_working_set_bytes`` /
    ``cce_vmem_budget_bytes`` — the resolved ``choose_blocks`` plan.
  * ``cce_backend_largest_buffer_elems{impl=...}`` /
    ``cce_backend_in_class{impl=...}`` — per-backend memory class measured
    from the optimized HLO via ``analysis/hlo.array_shape_census`` (AOT
    lowering, no execution), against the loss-zoo budget convention
    ``4·max(N·D, V·D)``; ``cce_backend_info`` carries each backend's
    *declared* class as a label for cross-checking.

``python -m repro.obs.kernels [--jsonl PATH]`` runs the whole set on the
peaked-problem oracle, asserts the bitmap stays a superset of Alg. 4
(kernel-parity-with-metrics smoke; CI uploads the JSONL), and prints the
Prometheus exposition.
"""

from __future__ import annotations

import numpy as np

from repro.obs import metrics as M


def record_cce_gauges(registry: M.Registry, E, C, x, cfg=None, *,
                      alg4_oracle: bool = False) -> dict:
    """Gauge the live-block fraction + block plan for one (E, C, x) probe.

    Runs the real forward kernel with bitmap emission (O(N·D + V·D), same
    class as training). ``alg4_oracle=True`` additionally evaluates the
    exact recompute statistic via the dense oracle — probe sizes only.
    Returns the recorded values as a dict (callers log or assert on it).
    """
    from repro.kernels import ops

    bitmap, (bn, bv) = ops.live_block_bitmap(E, C, x, cfg)
    bm = np.asarray(bitmap)
    n, d = (E.shape[0] * E.shape[1], E.shape[2]) if E.ndim == 3 \
        else E.shape
    plan = ops.kernel_plan(n, C.shape[0], d, E.dtype.itemsize, cfg)
    out = {
        "cce_live_block_fraction": float(bm.mean()),
        "cce_live_blocks": int(bm.sum()),
        "cce_total_blocks": int(bm.size),
        "cce_block_n": bn,
        "cce_block_v": bv,
        "cce_vmem_working_set_bytes": plan["vmem_working_set_bytes"],
        "cce_vmem_budget_bytes": plan["vmem_budget_bytes"],
    }
    if alg4_oracle:
        from repro.kernels import ref
        from repro.kernels.cce_bwd import DEFAULT_FILTER_EPS

        eps = cfg.filter_eps if cfg is not None else DEFAULT_FILTER_EPS
        softcap = cfg.softcap if cfg is not None else None
        rec = ref.ref_block_live(
            E.reshape(-1, E.shape[-1]) if E.ndim == 3 else E, C,
            x.reshape(-1) if x.ndim > 1 else x, bn, bv, eps,
            softcap=softcap)
        if np.any(rec & ~bm):
            raise AssertionError(
                "fwd bitmap dropped a block the Alg. 4 statistic keeps — "
                "the conservative-superset contract is broken")
        out["cce_live_block_fraction_alg4"] = float(rec.mean())
    for name, val in out.items():
        registry.gauge(name).set(val)
    return out


def record_backend_memory_gauges(registry: M.Registry, *, n: int = 2048,
                                 d: int = 256, v: int = 16384,
                                 impls=None) -> dict:
    """Measure each backend's memory class from its optimized HLO and
    gauge it. AOT lowering only — nothing executes, so the paper-style
    verdict is honest even for the dense baseline at sizes that would
    not fit. Returns {impl: largest_buffer_elems}."""
    import jax
    import jax.numpy as jnp

    from repro import backends
    from repro.analysis import hlo as hlo_an
    from repro.analysis.checks.memclass import (CCE_CLASS, census_budget,
                                                classify_elems)
    from repro.core import cross_entropy

    budget = census_budget(n, v, d)
    registry.gauge("cce_backend_budget_elems").set(budget)
    out = {}
    for name in impls or backends.list_backends():
        be = backends.get(name)

        def f(E, C, x, impl=name):
            return cross_entropy(E, C, x, impl=impl, reduction="mean")

        text = jax.jit(jax.value_and_grad(f, argnums=(0, 1))).lower(
            jax.ShapeDtypeStruct((n, d), jnp.bfloat16),
            jax.ShapeDtypeStruct((v, d), jnp.bfloat16),
            jax.ShapeDtypeStruct((n,), jnp.int32)).compile().as_text()
        elems = hlo_an.array_shape_census(text, top=1)[0][0]
        out[name] = elems
        labels = {"impl": name}
        registry.gauge("cce_backend_largest_buffer_elems", labels).set(
            elems)
        registry.gauge("cce_backend_in_class", labels).set(
            1.0 if classify_elems(elems, n=n, v=v, d=d) == CCE_CLASS
            else 0.0)
        registry.gauge("cce_backend_info", {
            "impl": name, "memory_class": be.memory_class}).set(1.0)
    return out


def main(argv=None):
    """Kernel observability smoke: gauges on the peaked-problem oracle,
    superset assertion, JSONL trace + Prometheus exposition."""
    import argparse

    from repro.kernels import CCEConfig, ref
    from repro.obs import prom
    from repro.obs.trace import JsonlSink, Tracer

    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default=None, metavar="PATH",
                    help="write the metric snapshot as a JSONL trace")
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--v", type=int, default=1024)
    ap.add_argument("--census-v", type=int, default=16384,
                    help="vocab for the per-backend HLO census (lowering "
                         "only; larger keeps the verdict sharp)")
    args = ap.parse_args(argv)

    reg = M.Registry()
    E, C, x, _ = ref.peaked_problem(args.n, args.d, args.v)
    cfg = CCEConfig(block_n=32, block_v=128)
    tracer = Tracer(JsonlSink(args.jsonl) if args.jsonl else None)
    with tracer.span("record_cce_gauges", n=args.n, d=args.d, v=args.v):
        vals = record_cce_gauges(reg, E, C, x, cfg, alg4_oracle=True)
    with tracer.span("record_backend_memory_gauges", v=args.census_v):
        record_backend_memory_gauges(reg, v=args.census_v)
    tracer.snapshot(reg)
    if tracer.sink is not None:
        tracer.sink.close()
    print(prom.exposition(reg), end="")
    live, alg4 = (vals["cce_live_block_fraction"],
                  vals["cce_live_block_fraction_alg4"])
    assert live < 1.0, (
        "peaked problem filtered nothing — bitmap emission regressed")
    print(f"# live-block fraction {live:.4f} (bitmap) >= {alg4:.4f} "
          f"(Alg. 4 oracle): superset OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
