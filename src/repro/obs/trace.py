"""Span-based tracing with a JSONL event sink (DESIGN.md §8).

Event stream format — one JSON object per line, every record carrying a
``type`` and a ``ts`` (unix seconds, float):

  {"type": "event", "ts": ..., "name": ..., ...attrs}
  {"type": "span",  "ts": <start>, "dur": <seconds>, "name": ..., ...attrs}
  {"type": "metrics", "ts": ..., "metrics": [...]}   (registry snapshots)

Spans come in two shapes:

  * lexical — ``with tracer.span("scorer"):`` for work enclosed by one
    frame;
  * keyed — ``tracer.begin("request", key)`` ... ``tracer.end(key)`` for
    lifecycles that cross function boundaries (a serve request lives from
    admission to retirement across many engine steps). ``annotate`` adds
    attributes mid-flight; ``end`` emits the single ``span`` record, with
    an optional explicit ``ts_end`` so the emitter can attribute the end
    to a reconstructed device-step time instead of "now" (how the engine
    keeps per-request spans honest under ``--sync-every > 1``).

The sink is explicitly flushed per record by default: a crashed run keeps
its flight-recorder tail, which is the point of having one. A ``Tracer``
with no sink is a no-op (cheap enough to leave in production paths), so
callers hold a tracer unconditionally, mirroring ``metrics.NULL``.
"""

from __future__ import annotations

import contextlib
import json
import threading
import time


class JsonlSink:
    """Append-only JSONL writer; thread-safe, one ``write()`` per event."""

    def __init__(self, path, *, flush_every: int = 1):
        self._f = open(path, "a")
        self.path = path
        self._lock = threading.Lock()
        self._flush_every = max(1, int(flush_every))
        self._pending = 0

    def write(self, record: dict) -> None:
        line = json.dumps(record, separators=(",", ":"), sort_keys=True,
                          default=float)
        with self._lock:
            self._f.write(line + "\n")
            self._pending += 1
            if self._pending >= self._flush_every:
                self._f.flush()
                self._pending = 0

    def close(self) -> None:
        with self._lock:
            self._f.flush()
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class Tracer:
    """Emits event/span records to a sink; no sink -> every call no-ops."""

    def __init__(self, sink: JsonlSink | None = None, *, clock=time.time):
        self.sink = sink
        self._clock = clock
        self._open: dict = {}          # key -> (name, t_start, attrs)

    @property
    def enabled(self) -> bool:
        return self.sink is not None

    def emit(self, record: dict) -> None:
        if self.sink is not None:
            self.sink.write(record)

    def event(self, name: str, ts: float | None = None, **attrs) -> None:
        if self.sink is None:
            return
        self.emit({"type": "event", "name": name,
                   "ts": self._clock() if ts is None else ts, **attrs})

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        if self.sink is None:
            yield self
            return
        t0 = self._clock()
        try:
            yield self
        finally:
            self.emit({"type": "span", "name": name, "ts": t0,
                       "dur": self._clock() - t0, **attrs})

    # -- keyed spans (cross-frame lifecycles) ---------------------------

    def begin(self, name: str, key, ts: float | None = None,
              **attrs) -> None:
        if self.sink is None:
            return
        self._open[key] = (name, self._clock() if ts is None else ts,
                           dict(attrs))

    def annotate(self, key, **attrs) -> None:
        if self.sink is None or key not in self._open:
            return
        self._open[key][2].update(attrs)

    def end(self, key, ts_end: float | None = None, **attrs) -> None:
        if self.sink is None:
            return
        entry = self._open.pop(key, None)
        if entry is None:
            return
        name, t0, acc = entry
        acc.update(attrs)
        t1 = self._clock() if ts_end is None else ts_end
        self.emit({"type": "span", "name": name, "ts": t0,
                   "dur": t1 - t0, **acc})

    def snapshot(self, registry) -> None:
        """Write the registry's current metric values as one record."""
        if self.sink is not None:
            self.emit(registry.snapshot(ts=self._clock()))


#: Shared disabled tracer — the ``tracer or trace.NULL`` default.
NULL = Tracer(None)


def read_jsonl(path) -> list:
    """Load a trace back (tests, offline analysis): list of dict records."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
