"""Prometheus text exposition (format 0.0.4) + optional scrape endpoint.

``exposition(registry)`` renders every instrument in the registry:

    # TYPE serve_ttft_seconds histogram
    serve_ttft_seconds_bucket{le="0.01"} 3
    ...
    serve_ttft_seconds_sum 0.042
    serve_ttft_seconds_count 5
    # TYPE serve_queue_depth gauge
    serve_queue_depth 2

``start_http_server(registry, port)`` serves it at ``/metrics`` from a
daemon thread (stdlib ``http.server`` only — no dependency; this is a
debug/scrape endpoint, not a production ingress). Returns the server so
callers can read the bound port (``server.server_address[1]``, useful with
``port=0``) and ``shutdown()`` it.
"""

from __future__ import annotations

import http.server
import threading

from repro.obs.metrics import Counter, Gauge, Histogram, Registry


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(labels: tuple, extra: tuple = ()) -> str:
    items = tuple(labels) + tuple(extra)
    if not items:
        return ""
    body = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", r"\\").replace('"', r"\""))
        for k, v in items)
    return "{%s}" % body


def exposition(registry: Registry) -> str:
    """Render the whole registry in Prometheus text format."""
    lines: list = []
    seen_type: set = set()
    for m in registry.collect():
        if isinstance(m, Histogram):
            kind = "histogram"
        elif isinstance(m, Counter):
            kind = "counter"
        elif isinstance(m, Gauge):
            kind = "gauge"
        else:                                   # pragma: no cover
            continue
        if m.name not in seen_type:
            lines.append(f"# TYPE {m.name} {kind}")
            seen_type.add(m.name)
        if isinstance(m, Histogram):
            for ub, c in m.cumulative():
                lines.append(
                    f"{m.name}_bucket"
                    f"{_fmt_labels(m.labels, (('le', _fmt_value(ub)),))}"
                    f" {c}")
            lines.append(f"{m.name}_bucket"
                         f"{_fmt_labels(m.labels, (('le', '+Inf'),))}"
                         f" {m.count}")
            lines.append(f"{m.name}_sum{_fmt_labels(m.labels)} "
                         f"{_fmt_value(m.sum)}")
            lines.append(f"{m.name}_count{_fmt_labels(m.labels)} "
                         f"{m.count}")
        else:
            lines.append(f"{m.name}{_fmt_labels(m.labels)} "
                         f"{_fmt_value(m.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


class _MetricsHandler(http.server.BaseHTTPRequestHandler):
    registry: Registry = None       # set per server subclass

    def do_GET(self):               # noqa: N802 (stdlib naming)
        if self.path.rstrip("/") not in ("", "/metrics"):
            self.send_error(404)
            return
        body = exposition(self.registry).encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):   # quiet: scrapes are not stdout news
        pass


def start_http_server(registry: Registry, port: int = 0,
                      addr: str = "127.0.0.1"):
    """Serve ``exposition(registry)`` at /metrics from a daemon thread."""
    handler = type("Handler", (_MetricsHandler,), {"registry": registry})
    server = http.server.ThreadingHTTPServer((addr, port), handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="repro-obs-metrics")
    thread.start()
    return server
