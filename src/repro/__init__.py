"""repro — Cut Cross-Entropy (CCE) training/inference framework in JAX.

Reproduction + extension of "Cut Your Losses in Large-Vocabulary Language
Models" (Wijmans et al., ICLR 2025) targeting multi-pod TPU meshes.
"""

__version__ = "0.1.0"
