"""Model core: a composable LM covering all ten assigned architectures.

One parametric decoder-only transformer (``init_lm`` / ``lm_hidden`` /
``train_loss`` / ``serve_step``) whose per-layer temporal mixer is selected
by ``ModelConfig.layer_pattern`` — full/sliding-window attention, RG-LRU, or
RWKV-6 — and whose channel mixer is a dense or MoE MLP. An encoder-decoder
variant (seamless) reuses the same blocks with a bidirectional encoder and
cross-attention.

Layers are applied with ``lax.scan`` over *pattern groups* (stacked params),
optionally wrapped in ``jax.checkpoint`` (cfg.remat="block"): HLO stays
small and activation memory is one residual per group — the production
configuration the dry-run lowers. The LM head resolves its loss from the
``repro.losses`` registry (plain NLL by default); every registry loss is
built on the CCE primitive, so the full (N, |V|) logit matrix never exists
in the train step regardless of which loss is configured.

Sharding is injected via ``repro.sharding.constraints.constrain`` tags; the
model code itself is mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro import losses as losses_api
from repro.core.api import cross_entropy
from repro.kernels.ref import IGNORE_INDEX
from repro.models import layers as L
from repro.models import recurrent as R
from repro.sharding.constraints import constrain

ATTN_KINDS = ("attn", "swa")


# ---------------------------------------------------------------------------
# Parameter init.
# ---------------------------------------------------------------------------

def _init_block(key, cfg, kind):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    p = {"ln1": L.init_rmsnorm(d, dt), "ln2": L.init_rmsnorm(d, dt)}
    if kind in ATTN_KINDS:
        p["mixer"] = L.init_attention(ks[0], d, cfg.num_heads,
                                      cfg.num_kv_heads,
                                      cfg.resolved_head_dim, dt)
    elif kind == "rglru":
        p["mixer"] = R.init_rglru_block(ks[0], d, cfg.ssm, dt)
    elif kind == "rwkv6":
        p["mixer"] = R.init_rwkv6_block(ks[0], d, cfg.ssm, dt)
    else:
        raise ValueError(kind)
    if cfg.moe is not None:
        p["mlp"] = L.init_moe(ks[1], d, cfg.moe, dt)
    elif kind == "rwkv6":
        p["mlp"] = R.init_rwkv_channel_mix(ks[1], d, cfg.d_ff, dt)
    else:
        p["mlp"] = L.init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_activation, dt)
    return p


def _pattern_split(cfg):
    """(pattern, n_groups, tail_kinds): layers = groups x pattern + tail."""
    p = tuple(cfg.layer_pattern)
    n_groups = cfg.num_layers // len(p)
    tail = cfg.pattern_for(cfg.num_layers)[n_groups * len(p):]
    return p, n_groups, tail


def init_lm(key, cfg):
    """Returns the full parameter pytree for a decoder-only LM."""
    dt = jnp.dtype(cfg.dtype)
    pattern, n_groups, tail = _pattern_split(cfg)
    k_embed, k_blocks, k_tail, k_head, k_enc = jax.random.split(key, 5)

    v_pad = cfg.padded_vocab_size  # Megatron-style padding (configs/base.py)
    params = {
        "embed": (jax.random.normal(k_embed, (v_pad, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    # stacked params per pattern position: leading axis = n_groups
    blocks = []
    bkeys = jax.random.split(k_blocks, len(pattern))
    for pos, kind in enumerate(pattern):
        gkeys = jax.random.split(bkeys[pos], max(n_groups, 1))
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_block(gkeys[g], cfg, kind) for g in range(n_groups)])
        blocks.append(stacked)
    params["blocks"] = blocks
    if tail:
        tkeys = jax.random.split(k_tail, len(tail))
        params["tail"] = [_init_block(tkeys[i], cfg, kind)
                          for i, kind in enumerate(tail)]
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            k_head, (v_pad, cfg.d_model)) * 0.02).astype(dt)

    if cfg.is_encdec:
        ekeys = jax.random.split(k_enc, cfg.encoder_layers + 2)
        params["encoder"] = {
            "blocks": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_init_block(ekeys[i], cfg, "attn")
                  for i in range(cfg.encoder_layers)]),
            "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        }
        params["cross"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[{"ln": L.init_rmsnorm(cfg.d_model, dt),
               "attn": L.init_attention(
                   jax.random.split(ekeys[-1], cfg.num_layers)[i],
                   cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                   cfg.resolved_head_dim, dt)}
              for i in range(cfg.num_layers)])
    return params


# ---------------------------------------------------------------------------
# Block application.
# ---------------------------------------------------------------------------

def _rope_for(cfg, positions, kv_positions=None):
    hd = cfg.resolved_head_dim
    if cfg.rope_sections is not None:
        if positions.ndim == 2:  # (B, S) text-only -> same stream 3x
            positions = jnp.broadcast_to(positions[None],
                                         (3,) + positions.shape)
        cos, sin = L.mrope_cos_sin(positions, hd, cfg.rope_theta,
                                   cfg.rope_sections)
    else:
        cos, sin = L.rope_cos_sin(positions, hd, cfg.rope_theta)
    if kv_positions is None:
        return (cos, sin, cos, sin)
    if cfg.rope_sections is not None and kv_positions.ndim == 2:
        kv_positions = jnp.broadcast_to(kv_positions[None],
                                        (3,) + kv_positions.shape)
        kcos, ksin = L.mrope_cos_sin(kv_positions, hd, cfg.rope_theta,
                                     cfg.rope_sections)
    else:
        kcos, ksin = L.rope_cos_sin(kv_positions, hd, cfg.rope_theta)
    return (cos, sin, kcos, ksin)


def _apply_block(params, x, kind, cfg, cos_sin, cache, cache_index, decode,
                 valid_len=None, page_table=None):
    """One (mixer + MLP) block with pre-norms. Returns (x, new_cache, aux).

    valid_len (B,), decode only: per-row count of valid tokens in a
    chunked-prefill step — tail positions past it are padding and must
    not enter the KV cache or the recurrent states.

    page_table (B, n_logical) int32, decode only: logical->physical page
    map for block-paged attention caches (repro.serve.kvpool). One table
    serves every layer; non-attention mixers ignore it.
    """
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.sliding_window if kind == "swa" else None
        out, new_cache = L.multi_head_attention(
            params["mixer"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            cos_sin=cos_sin, causal=True, window=window,
            softcap=cfg.attn_softcap, cache=cache, cache_index=cache_index,
            valid_len=valid_len, page_table=page_table)
    elif kind == "rglru":
        out, new_cache = R.rglru_block(params["mixer"], h, cfg.ssm,
                                       state=cache, decode=decode,
                                       valid_len=valid_len)
    elif kind == "rwkv6":
        out, new_cache = R.rwkv6_mixer(params["mixer"], h, cfg.ssm,
                                       state=cache, decode=decode,
                                       valid_len=valid_len)
    else:
        raise ValueError(kind)
    x = x + constrain(out, "residual")

    h = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        out, aux = L.moe_mlp(params["mlp"], h, cfg.moe)
    elif kind == "rwkv6":
        out, shift = R.rwkv_channel_mix(
            params["mlp"], h,
            state=cache.get("mlp_shift") if cache else None, decode=decode,
            valid_len=valid_len)
        if new_cache is not None:
            new_cache = dict(new_cache)
            new_cache["mlp_shift"] = shift
    else:
        out = L.mlp(params["mlp"], h, cfg.mlp_activation)
    x = x + constrain(out, "residual")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Forward pass (hidden states).
# ---------------------------------------------------------------------------

def _embed(params, cfg, batch):
    if cfg.input_mode == "embeds" and "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        tokens = batch["tokens"]
        safe = jnp.where(tokens == IGNORE_INDEX, 0, tokens)
        x = jnp.take(params["embed"], safe, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, "residual")


def lm_hidden(params, cfg, batch, cache=None, cache_index=None,
              enc_out=None, valid_len=None):
    """Run the (decoder) stack. Returns (hidden (B,S,d), new_cache, aux).

    cache: pytree from ``init_cache`` for decode; None for teacher forcing.
    valid_len (B,): per-row valid-token count for chunked prefill (decode
    with S > 1); tail positions are padding (see ``serve_prefill``).
    """
    decode = cache is not None
    x = _embed(params, cfg, batch)
    b, s, _ = x.shape

    if decode:
        # cache_index: scalar (shared timeline) or (B,) per-row positions
        ci = jnp.asarray(cache_index, jnp.int32).reshape(-1, 1)
        positions = jnp.broadcast_to(ci + jnp.arange(s), (b, s))
        if cfg.rope_sections is not None:
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    elif "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos_sin = _rope_for(cfg, positions)

    pattern, n_groups, tail = _pattern_split(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    cross_params = params.get("cross")
    # one page table serves every paged layer; it is loop-invariant, so it
    # rides into the scan as a closure, not as scanned xs
    page_table = cache.get("pt") if decode else None

    def group_body(carry, xs):
        x, aux = carry
        block_params = xs["blocks"]
        block_caches = xs.get("cache")
        cross_p = xs.get("cross")
        new_caches = []
        for pos, kind in enumerate(pattern):
            c = block_caches[pos] if block_caches is not None else None
            x, nc, a = _apply_block(block_params[pos], x, kind, cfg, cos_sin,
                                    c, cache_index, decode,
                                    valid_len=valid_len,
                                    page_table=page_table)
            if cross_p is not None:
                x = _apply_cross(jax.tree.map(lambda a: a[pos], cross_p),
                                 x, cfg, enc_out)
            new_caches.append(nc)
            aux = aux + a
        ys = {"cache": new_caches} if block_caches is not None else {}
        return (x, aux), ys

    if cfg.remat == "block":
        group_body = jax.checkpoint(group_body)
    elif cfg.remat == "save_dots":
        # checkpoint the block but keep large matmul outputs (MLP up/gate,
        # attention projections) resident instead of recomputing them in
        # the backward — trades ~2 GB/device of saved activations for one
        # fewer recompute pass over the dominant matmuls (§Perf gemma G2).
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = {"blocks": params["blocks"]}
    if decode:
        xs["cache"] = cache["groups"]
    if cross_params is not None:
        # cross params are stacked over all layers; regroup to (groups, P)
        xs["cross"] = jax.tree.map(
            lambda a: a[:n_groups * len(pattern)].reshape(
                (n_groups, len(pattern)) + a.shape[1:]), cross_params)

    if n_groups > 0:
        (x, aux_total), ys = jax.lax.scan(group_body, (x, aux_total), xs)
    else:
        ys = {}

    new_cache = {"groups": ys.get("cache")} if decode else None
    if decode and page_table is not None:
        # the table itself is host-managed (kvpool); the model only reads
        # it, so it passes through unchanged
        new_cache["pt"] = page_table

    for i, kind in enumerate(tail):
        c = cache["tail"][i] if decode else None
        x, nc, a = _apply_block(params["tail"][i], x, kind, cfg, cos_sin,
                                c, cache_index, decode,
                                valid_len=valid_len,
                                page_table=page_table)
        aux_total = aux_total + a
        if decode:
            new_cache.setdefault("tail", []).append(nc)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_cache, aux_total


def _apply_cross(cross_p, x, cfg, enc_out):
    h = L.rmsnorm(cross_p["ln"], x, cfg.norm_eps)
    out, _ = L.multi_head_attention(
        cross_p["attn"], h, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        cos_sin=None, causal=False, kv_x=enc_out)
    return x + constrain(out, "residual")


def encode(params, cfg, enc_batch):
    """Bidirectional encoder over stub frontend embeddings (B, S_enc, d)."""
    enc = params["encoder"]
    x = enc_batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim,
                              cfg.rope_theta)
    cos_sin = (cos, sin, cos, sin)

    def body(carry, block_params):
        x = carry
        h = L.rmsnorm(block_params["ln1"], x, cfg.norm_eps)
        out, _ = L.multi_head_attention(
            block_params["mixer"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            cos_sin=cos_sin, causal=False)
        x = x + out
        h = L.rmsnorm(block_params["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(block_params["mlp"], h, cfg.mlp_activation)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(body_fn, x, enc["blocks"])
    return L.rmsnorm(enc["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Losses / serving.
# ---------------------------------------------------------------------------

def classifier_matrix(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["head"]


def train_loss(params, cfg, batch, loss_impl=None, loss_fn=None,
               loss: str = "nll", loss_kwargs=None, mesh=None,
               vocab_axis: str = "model", token_axes=("data",),
               cce_cfg=None):
    """Scalar training loss (+ MoE aux). batch needs "labels".

    loss / loss_kwargs: a ``repro.losses`` registry name and its
    hyper-parameters — every registry loss lowers onto the CCE primitive,
    so swapping losses never changes the head's memory class. A
    ``loss_weights`` entry in the batch (shape of labels) feeds per-token
    weighting (e.g. completion-only fine-tuning with loss="weighted").

    The head is one ``repro.core.cross_entropy`` call: ``loss_impl`` (or
    ``cfg.loss_impl``) names a :mod:`repro.backends` entry, resolved by
    capability — asking an NLL-only baseline for a registry loss raises an
    error listing the backends that can serve it. Passing ``mesh`` routes
    the same resolved backend through the vocab-parallel combine
    (production train step; C sharded over ``vocab_axis``).

    loss_fn: optional low-level override (E, C, labels) -> per-token loss
    for bespoke heads the registry cannot express.

    cce_cfg: optional :class:`repro.kernels.ops.CCEConfig` carrying the
    kernel-level knobs (sort_vocab, filter modes, accumulator) down to the
    resolved backend — the CLI flags on launch/train and launch/dryrun end
    up here.
    """
    enc_out = encode(params, cfg, batch) if cfg.is_encdec else None
    hidden, _, aux = lm_hidden(params, cfg, batch, enc_out=enc_out)
    hidden = constrain(hidden, "residual")
    C = classifier_matrix(params, cfg)
    labels = batch["labels"]
    e_flat = hidden.reshape(-1, cfg.d_model)
    l_flat = labels.reshape(-1)
    if loss_fn is not None:
        if loss != "nll" or loss_kwargs or "loss_weights" in batch:
            raise ValueError(
                "loss_fn overrides the loss head entirely: it cannot be "
                f"combined with loss={loss!r} / loss_kwargs / "
                "batch['loss_weights'] — fold those into loss_fn itself")
        nll = loss_fn(e_flat, C, l_flat)
        loss_val = losses_api.base.reduce_loss(nll, l_flat, "mean")
    else:
        loss_obj = losses_api.get_loss(loss, **(loss_kwargs or {}))
        if not loss_obj.trainable:
            raise ValueError(
                f"loss {loss!r} is a scoring objective, not a training "
                f"loss; pick one of "
                f"{[n for n in losses_api.list_losses() if n != loss]}")
        weights = batch.get("loss_weights")
        if weights is not None:
            weights = weights.reshape(-1)
        loss_val = cross_entropy(
            e_flat, C, l_flat, loss=loss_obj,
            impl=loss_impl or cfg.loss_impl, softcap=cfg.logit_softcap,
            reduction="mean", weights=weights, mesh=mesh,
            vocab_axis=vocab_axis, token_axes=token_axes, cfg=cce_cfg)
    if cfg.moe is not None:
        loss_val = loss_val + cfg.moe.router_aux_loss * aux
    return loss_val


def init_cache(cfg, batch_size, max_len, dtype=None, kv_page_size=None,
               kv_pages=None):
    """Decode cache pytree: stacked per group x pattern position.

    Every row's slot is independent: ring-buffer position metadata is kept
    per row, so a continuous-batching scheduler can run each row on its own
    timeline (per-row ``cache_index``) and recycle one row's slot without
    touching the others (``reset_cache_rows``).

    kv_page_size / kv_pages: block-paged layout for *full-attention*
    caches (repro.serve.kvpool). Each "attn" layer gets a page pool
    ``k_pages``/``v_pages`` of shape (kv_pages, kv_page_size, hkv, hd)
    instead of per-slot rows, and the cache gains one shared page table
    ``pt`` (B, ceil(max_len / kv_page_size)) int32, -1 = unmapped.
    kv_pages defaults to the dense-equivalent pool size. SWA ring buffers
    and recurrent states are already O(1)-bounded per row and stay
    slot-dense.
    """
    dt = jnp.dtype(dtype or cfg.dtype)
    pattern, n_groups, tail = _pattern_split(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kv_pages is not None and kv_page_size is None:
        raise ValueError("kv_pages requires kv_page_size")
    if kv_page_size is not None:
        if kv_page_size < 1:
            raise ValueError(f"kv_page_size must be >= 1, got "
                             f"{kv_page_size}")
        n_logical = -(-max_len // kv_page_size)
        if kv_pages is None:
            kv_pages = batch_size * n_logical
        if kv_pages < 1:
            raise ValueError(f"kv_pages must be >= 1, got {kv_pages}")

    def one(kind):
        if kind in ATTN_KINDS:
            length = max_len
            if kind == "swa" and cfg.sliding_window is not None:
                length = min(max_len, cfg.sliding_window)
            if kv_page_size is not None and kind == "attn":
                return {"k_pages": jnp.zeros(
                            (kv_pages, kv_page_size, hkv, hd), dt),
                        "v_pages": jnp.zeros(
                            (kv_pages, kv_page_size, hkv, hd), dt)}
            c = {"k": jnp.zeros((batch_size, length, hkv, hd), dt),
                 "v": jnp.zeros((batch_size, length, hkv, hd), dt)}
            if length < max_len:  # ring buffer: per-row absolute positions
                c["pos"] = jnp.full((batch_size, length), -1, jnp.int32)
            return c
        if kind == "rglru":
            return R.rglru_init_state(batch_size, cfg.ssm, cfg.d_model, dt)
        if kind == "rwkv6":
            st = R.rwkv6_init_state(batch_size, cfg.ssm, cfg.d_model, dt)
            st["mlp_shift"] = jnp.zeros((batch_size, 1, cfg.d_model), dt)
            return st
        raise ValueError(kind)

    cache = {"groups": [jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape),
        one(kind)) for kind in pattern]}
    if tail:
        cache["tail"] = [one(kind) for kind in tail]
    if kv_page_size is not None:
        cache["pt"] = jnp.full((batch_size, n_logical), -1, jnp.int32)
    return cache


def reset_cache_rows(cache, rows):
    """Reset the cache rows where ``rows`` (B,) bool is True to their
    initial state (slot recycling for continuous batching).

    Attention K/V and recurrent states re-init to zeros; ring-buffer
    ``pos`` metadata to -1 (the "never written" sentinel). Pure ``where``
    ops, so this jits and leaves the other rows' slots untouched.

    Paged caches: the page pools (``k_pages``/``v_pages``) have no batch
    axis and pages may be shared across rows, so zeroing them would
    corrupt live neighbours — page freeing happens host-side in
    :class:`repro.serve.kvpool.KVPool` instead, and recycling a slot here
    only unmaps its page-table row (``pt`` -> -1).
    """
    def reset(leaf, batch_axis, fill):
        shape = [1] * leaf.ndim
        shape[batch_axis] = leaf.shape[batch_axis]
        m = rows.reshape(shape)
        return jnp.where(m, jnp.full_like(leaf, fill), leaf)

    def walk(tree, batch_axis):
        if isinstance(tree, dict):
            return {k: (v if k in ("k_pages", "v_pages")
                        else reset(v, batch_axis, -1) if k == "pos"
                        else walk(v, batch_axis))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, batch_axis) for v in tree)
        return reset(tree, batch_axis, 0)

    # group caches are stacked (n_groups, B, ...); tail caches are (B, ...)
    out = {"groups": walk(cache["groups"], 1)}
    if "tail" in cache:
        out["tail"] = walk(cache["tail"], 0)
    if "pt" in cache:
        out["pt"] = reset(cache["pt"], 0, -1)
    return out


def select_cache_rows(mask, new_cache, old_cache):
    """Per-row cache merge: rows where ``mask`` (B,) is True take
    ``new_cache``, the rest keep ``old_cache`` (identical treedefs).

    The speculative draft loop uses this to commit a catch-up forward
    only for the rows that actually advanced this round — pure ``where``
    ops over the same axis conventions as :func:`reset_cache_rows`
    (groups stacked (n_groups, B, ...), tail (B, ...)). Paged pools
    (``k_pages``/``v_pages``) have no batch axis and the page *writes*
    are already row-disjoint (each row only touches its own mapped
    pages), so they pass through from ``new_cache``; ``pt`` is
    host-managed and merges per row like any other leaf.
    """
    def sel(new, old, batch_axis):
        shape = [1] * new.ndim
        shape[batch_axis] = new.shape[batch_axis]
        return jnp.where(mask.reshape(shape), new, old)

    def walk(new, old, batch_axis):
        if isinstance(new, dict):
            return {k: (new[k] if k in ("k_pages", "v_pages")
                        else walk(new[k], old[k], batch_axis))
                    for k in new}
        if isinstance(new, (list, tuple)):
            return type(new)(walk(n, o, batch_axis)
                             for n, o in zip(new, old))
        return sel(new, old, batch_axis)

    out = {"groups": walk(new_cache["groups"], old_cache["groups"], 1)}
    if "tail" in new_cache:
        out["tail"] = walk(new_cache["tail"], old_cache["tail"], 0)
    if "pt" in new_cache:
        out["pt"] = sel(new_cache["pt"], old_cache["pt"], 0)
    return out


def _lm_head(h_last, params, cfg, *, return_logits, sample, with_filter,
             with_sample=True):
    """Shared classifier tail for the serve entry points.

    ``return_logits=True`` (dense path, the golden oracle): projects the
    (B, D) last hidden states through the full classifier and returns
    (B, V) logits. ``return_logits=False`` routes through the fused
    projection->sample kernel instead — ``sample`` must then be a
    ``(keys, temperature, top_k, top_p)`` tuple of per-row vectors and the
    return value is ``(tokens (B,), logprobs (B,))``; the (B, V) logit
    matrix never exists and ``logit_softcap`` is applied inside the
    kernel's block loop.
    """
    C = classifier_matrix(params, cfg)
    if return_logits:
        logits = h_last.astype(jnp.float32) @ C.astype(jnp.float32).T
        if cfg.logit_softcap is not None:
            logits = cfg.logit_softcap * jnp.tanh(
                logits / cfg.logit_softcap)
        return logits[:, :cfg.vocab_size]
    from repro.serve import sampling  # deferred: serve imports this module
    keys, temperature, top_k, top_p = sample
    return sampling.sample_tokens_fused(
        h_last, C, keys, temperature, top_k, top_p,
        vocab=cfg.vocab_size, softcap=cfg.logit_softcap,
        with_filter=with_filter, with_sample=with_sample)


def serve_step(params, cfg, cache, tokens, cache_index, enc_out=None, *,
               return_logits=True, sample=None, with_filter=True,
               with_sample=True):
    """One decode step: tokens (B, 1) -> (logits (B, V), new cache).

    ``cache_index`` is a scalar (all rows share one timeline — the legacy
    lockstep engine) or a (B,) int vector of per-row positions (continuous
    batching: each row writes its KV slot and builds its causal mask at its
    own absolute time).

    With ``return_logits=False`` the step never materializes the (B, V)
    logits: ``sample=(keys, temperature, top_k, top_p)`` is fed into the
    fused projection->sample kernel (``kernels.decode_sample``) and the
    step returns ``((tokens, logprobs), new cache)`` instead — the
    serving-side dual of CCE. The dense mode stays the fallback and the
    golden oracle; the paper's §3.2 "inference is memory-cheap" claim
    only covers a single sequence's final position, not a full slot
    batch paying (B, V) every step.
    """
    batch = {"tokens": tokens}
    hidden, new_cache, _ = lm_hidden(params, cfg, batch, cache=cache,
                                     cache_index=cache_index, enc_out=enc_out)
    out = _lm_head(hidden[:, -1], params, cfg, return_logits=return_logits,
                   sample=sample, with_filter=with_filter,
                   with_sample=with_sample)
    return out, new_cache


def serve_prefill(params, cfg, cache, tokens, cache_index, valid_len,
                  enc_out=None, *, return_logits=True, sample=None,
                  with_filter=True, with_sample=True):
    """Chunked prefill: consume up to S tokens per row in ONE call.

    tokens (B, S); cache_index (B,) per-row absolute write position;
    valid_len (B,) in [1, S] — row b ingests ``tokens[b, :valid_len[b]]``
    at positions ``cache_index[b] .. cache_index[b] + valid_len[b] - 1``
    and everything past that is padding (never cached, never touching the
    recurrent states). Returns (logits (B, V) at each row's LAST VALID
    position, new cache) — exactly the logits ``valid_len`` one-token
    ``serve_step`` calls would have ended on, so a scheduler can fuse
    prompt ingestion for some rows with single-token decode for others
    (valid_len == 1) in the same jit. ``return_logits=False`` swaps the
    classifier tail for the fused projection->sample kernel exactly as in
    :func:`serve_step` (returns ``((tokens, logprobs), new cache)``).
    """
    if cfg.moe is not None:
        # serve must be drop-free: one-token decode never drops a token
        # (<= 1 slot per expert), so the chunked path may not either —
        # capacity e/k makes cap == tokens-per-row, the per-expert maximum
        moe = cfg.moe
        cap_free = moe.num_experts / moe.top_k
        if moe.capacity_factor < cap_free:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(moe, capacity_factor=cap_free))
    b, s = tokens.shape
    valid_len = jnp.asarray(valid_len, jnp.int32)
    hidden, new_cache, _ = lm_hidden(
        params, cfg, {"tokens": tokens}, cache=cache,
        cache_index=cache_index, enc_out=enc_out, valid_len=valid_len)
    last = jnp.clip(valid_len - 1, 0, s - 1)
    h_last = jnp.take_along_axis(hidden, last[:, None, None], axis=1)[:, 0]
    out = _lm_head(h_last, params, cfg, return_logits=return_logits,
                   sample=sample, with_filter=with_filter,
                   with_sample=with_sample)
    return out, new_cache


def serve_prefill_spec(params, cfg, cache, tokens, cache_index, valid_len,
                       enc_out=None):
    """Speculative verification forward: the :func:`serve_prefill`
    multi-token decode step, but returning EVERY position's final hidden
    state ``(B, S, D)`` instead of reducing to the last valid one.

    The speculative engine runs the draft window ``[t0, d1 .. dK]``
    through this, flattens to ``(B·S, D)`` and scores all positions with
    ONE fused decode sweep — per-token target logprobs without ever
    materializing ``(B, S, V)`` (DESIGN.md §12). Same drop-free MoE
    capacity forcing and per-row ``valid_len`` padding discipline as
    chunked prefill; positions past ``valid_len`` never enter the KV
    cache or recurrent states, but their (garbage) hidden states are
    still returned — callers mask them out.
    """
    if cfg.moe is not None:
        moe = cfg.moe
        cap_free = moe.num_experts / moe.top_k
        if moe.capacity_factor < cap_free:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(moe, capacity_factor=cap_free))
    valid_len = jnp.asarray(valid_len, jnp.int32)
    hidden, new_cache, _ = lm_hidden(
        params, cfg, {"tokens": tokens}, cache=cache,
        cache_index=cache_index, enc_out=enc_out, valid_len=valid_len)
    return hidden, new_cache
