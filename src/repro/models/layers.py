"""Neural-net building blocks (pure functions over param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; every layer has an ``init_*``
    returning params and an apply function taking (params, x, ...).
  * activations flow in the model dtype (bf16 by default); normalization,
    softmax and recurrence statistics are computed in f32.
  * attention uses a *chunked* (online-softmax, Rabe–Staats style) scan for
    long sequences so the (S, S) score matrix never materializes — the same
    memory discipline CCE applies to the classifier head; dense fallback for
    short sequences. This keeps the dry-run memory analysis honest.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import compat
from repro.sharding.constraints import current_rules as _current_rules

# Sequence length above which self-attention switches to the chunked scan.
DENSE_ATTN_MAX_SEQ = 2048
ATTN_CHUNK = 1024


def _he(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": _he(key, (d_in, d_out), scale, dtype)}


def dense(params, x):
    return x @ params["w"]


def init_rmsnorm(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + qwen2-vl M-RoPE).
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim, theta):
    """positions (..., S) int -> cos/sin (..., S, head_dim/2) f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                             / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, head_dim); cos/sin (B, S, head_dim/2) broadcast over H."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1)


def mrope_cos_sin(positions3, head_dim, theta, sections):
    """qwen2-vl M-RoPE: positions3 (3, B, S) for (t, h, w) position streams;
    frequency bands are split between the three streams per ``sections``
    (counts of half-dims, summing to head_dim/2)."""
    cos_all, sin_all = rope_cos_sin(positions3, head_dim, theta)  # (3,B,S,hd/2)
    idx = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])
    cos = jnp.take_along_axis(
        jnp.moveaxis(cos_all, 0, -1), idx[None, None, :, None], axis=-1)[..., 0]
    sin = jnp.take_along_axis(
        jnp.moveaxis(sin_all, 0, -1), idx[None, None, :, None], axis=-1)[..., 0]
    return cos, sin


# ---------------------------------------------------------------------------
# Attention (GQA / MQA; causal, sliding-window, bidirectional, cross).
# ---------------------------------------------------------------------------

def init_attention(key, d_model, num_heads, num_kv_heads, head_dim, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    return {
        "wq": _he(kq, (d_model, num_heads * head_dim), s, dtype),
        "wk": _he(kk, (d_model, num_kv_heads * head_dim), s, dtype),
        "wv": _he(kv, (d_model, num_kv_heads * head_dim), s, dtype),
        "wo": _he(ko, (num_heads * head_dim, d_model),
                  1.0 / math.sqrt(num_heads * head_dim), dtype),
    }


def _repeat_kv(k, num_heads):
    """(B, S, Hkv, hd) -> (B, S, H, hd) by repeating groups."""
    hkv = k.shape[2]
    if hkv == num_heads:
        return k
    return jnp.repeat(k, num_heads // hkv, axis=2)


def _dense_attn(q, k, v, *, causal, window, softcap, q_offset=0,
                kv_pos=None):
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd) -> (B,Sq,H,hd). f32 softmax.

    q_offset: scalar or per-row (B,) absolute query position (continuous
    batching gives every row its own timeline). kv_pos: optional (Sk,) or
    (B, Sk) absolute key positions (ring caches); defaults to arange(Sk).
    Unwritten ring slots carry pos = -1 and are masked off.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    sq, sk = q.shape[1], k.shape[1]
    # normalize to (B|1, Sq) query / (B|1, Sk) key position grids so the
    # mask broadcasts over heads as (B|1, 1, Sq, Sk)
    qpos = jnp.asarray(q_offset).reshape(-1, 1) + jnp.arange(sq)
    kpos = (jnp.arange(sk)[None] if kv_pos is None
            else jnp.asarray(kv_pos).reshape(-1, sk))
    mask = jnp.ones((max(qpos.shape[0], kpos.shape[0]), sq, sk), bool)
    if kv_pos is not None:
        mask &= (kpos >= 0)[:, None, :]
    if causal:
        mask &= kpos[:, None, :] <= qpos[:, :, None]
    if window is not None:
        mask &= kpos[:, None, :] > qpos[:, :, None] - window
    scores = jnp.where(mask[:, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def _chunked_attn(q, k, v, *, causal, window, softcap):
    """Memory-efficient attention: scan over KV chunks with an online
    softmax; the (Sq, Sk) score matrix exists one (Sq_blk, chunk) tile at a
    time. For sliding windows, only the chunks intersecting the band are
    visited (banded scan) so FLOPs are O(S·window), not O(S^2)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    ck = min(ATTN_CHUNK, sk)
    nk = sk // ck
    assert sk % ck == 0, (sk, ck)

    def kv_step(carry, idx):
        m, s, o = carry
        kc = jax.lax.dynamic_slice_in_dim(k, idx * ck, ck, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, idx * ck, ck, axis=1)
        # QK in the model dtype with f32 accumulation (MXU-native); the
        # softmax statistics and o-accumulator stay f32; the bounded
        # post-exp tile goes back to the model dtype for the PV matmul —
        # flash-attention's standard mixed precision.
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                            preferred_element_type=jnp.float32)
        scores = scores * scale
        if softcap is not None:
            scores = softcap * jnp.tanh(scores / softcap)
        qpos = jnp.arange(sq)[:, None]
        kpos = idx * ck + jnp.arange(ck)[None, :]
        mask = jnp.ones((sq, ck), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
        bmax = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m, bmax)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(scores - m_safe[..., None])
        corr = jnp.exp(m - m_safe)
        s = s * corr + jnp.sum(p, -1)
        # p stays f32 into the PV matmul: a bf16 cast here measured as a
        # net extra tile materialization on the dry-run (§Perf gemma G1).
        o = o * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vc,
            preferred_element_type=jnp.float32)
        return (m_new, s, o), None

    init = (jnp.full((b, h, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, hd), jnp.float32))
    (m, s, o), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
    out = o / jnp.maximum(s, 1e-37)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)  # (B, Sq, H, hd)


def _row_update(buf, upd, idx):
    """Write ``upd`` (B, Sq, ...) into ``buf`` (B, L, ...) at time index
    ``idx`` — a shared scalar (lockstep decode) or per-row (B,) vector
    (continuous batching, every row on its own timeline)."""
    if jnp.ndim(idx) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, upd, idx, 1)
    return jax.vmap(
        lambda b, u, i: jax.lax.dynamic_update_slice_in_dim(b, u, i, 0)
    )(buf, upd, idx)


def _masked_row_update(buf, upd, tgt, write):
    """Scatter ``upd`` (B, Sq, ...) rows into ``buf`` (B, L, ...) at
    per-token positions ``tgt`` (B, Sq); tokens with ``write`` False are
    dropped (their target is pushed out of bounds, mode="drop").

    Chunked prefill's ragged tails make a plain ``dynamic_update_slice``
    unsafe twice over: invalid tail tokens must not land in the cache, and
    a row whose chunk extends past L would have its start clamped and
    clobber *earlier* valid positions. Valid targets are unique per row, so
    the scatter is deterministic."""
    b, sq = tgt.shape
    safe = jnp.where(write, tgt, buf.shape[1])
    return buf.at[jnp.arange(b)[:, None], safe].set(upd, mode="drop")


def multi_head_attention(params, x, *, num_heads, num_kv_heads, head_dim,
                         cos_sin=None, causal=True, window=None,
                         softcap=None, kv_x=None, cache=None,
                         cache_index=None, valid_len=None, page_table=None):
    """Self- or cross-attention with optional KV cache (decode).

    cache: dict(k=(B, S_cache, Hkv, hd), v=...) updated at ``cache_index``
    when decoding. ``cache_index`` may be a scalar (all rows on one
    timeline) or a (B,) vector of per-row positions. With Sq > 1 (chunked
    prefill) each row writes ``valid_len`` (B,) KV positions — tail tokens
    past a row's valid length are padding: never cached, and causally
    invisible to valid queries. Returns (out, new_cache).

    Paged variant: cache holds page *pools* ``k_pages``/``v_pages`` of
    shape (num_pages, page_size, Hkv, hd) shared by all rows, and
    ``page_table`` (B, n_logical) int32 maps each row's logical pages to
    physical ones (-1 = unmapped; see repro.serve.kvpool). Reads gather a
    per-row logical KV view through the table; writes scatter into the
    flattened pool. Unmapped/unwritten logical slots are masked via
    kv_pos and causality exactly like ring caches.
    """
    b, sq, _ = x.shape
    kv_in = x if kv_x is None else kv_x
    q = dense({"w": params["wq"]}, x).reshape(b, sq, num_heads, head_dim)
    k = dense({"w": params["wk"]}, kv_in).reshape(
        b, kv_in.shape[1], num_kv_heads, head_dim)
    v = dense({"w": params["wv"]}, kv_in).reshape(
        b, kv_in.shape[1], num_kv_heads, head_dim)

    if cos_sin is not None:
        cos_q, sin_q, cos_k, sin_k = cos_sin
        q = apply_rope(q, cos_q, sin_q).astype(x.dtype)
        k = apply_rope(k, cos_k, sin_k).astype(x.dtype)

    q_offset = 0
    kv_pos = None
    if cache is not None:
        causal = True
        q_offset = cache_index
        if "k_pages" in cache:
            # Block-paged cache: one pool of pages shared by every row,
            # indirected through ``page_table``. A shared-prefix page is
            # mapped by several rows at once but written by none of them
            # (rows write only from their private ``cache_index`` onward),
            # so scatter targets are unique and copy-free reuse is safe.
            kp, vp = cache["k_pages"], cache["v_pages"]
            n_phys, psize = kp.shape[0], kp.shape[1]
            pt = page_table                              # (B, n_logical)
            ci = jnp.broadcast_to(
                jnp.asarray(cache_index, jnp.int32).reshape(-1), (b,))
            n = (jnp.full((b,), sq, jnp.int32) if valid_len is None
                 else jnp.broadcast_to(
                     jnp.asarray(valid_len, jnp.int32), (b,)))
            j = jnp.arange(sq)[None]
            abs_pos = ci[:, None] + j                    # (B, Sq)
            lpage = jnp.clip(abs_pos // psize, 0, pt.shape[1] - 1)
            phys = jnp.take_along_axis(pt, lpage, axis=1)
            write = (j < n[:, None]) & (phys >= 0)
            tgt = phys * psize + abs_pos % psize         # flat pool index
            safe = jnp.where(write, tgt, n_phys * psize)
            kp_flat = kp.reshape((n_phys * psize,) + kp.shape[2:])
            vp_flat = vp.reshape((n_phys * psize,) + vp.shape[2:])
            kp_flat = kp_flat.at[safe].set(k, mode="drop")
            vp_flat = vp_flat.at[safe].set(v, mode="drop")
            new_cache = {"k_pages": kp_flat.reshape(kp.shape),
                         "v_pages": vp_flat.reshape(vp.shape)}
            # gather the row-logical KV view (B, L, Hkv, hd); unmapped
            # pages read page 0 but are masked off via kv_pos = -1, and
            # mapped-but-unwritten positions are causally invisible
            jj = jnp.arange(pt.shape[1] * psize)
            phys_all = pt[:, jj // psize]                # (B, L)
            src = jnp.clip(phys_all, 0, n_phys - 1) * psize + jj % psize
            k = kp_flat[src]
            v = vp_flat[src]
            kv_pos = jnp.where(phys_all >= 0, jj[None], -1)
        elif "pos" in cache and sq == 1:
            # Ring buffer (sliding-window cache, length W << context): write
            # at slot t mod W; the mask comes from the stored absolute
            # positions (B, W), so RoPE'd keys stay valid and each row can
            # sit at a different absolute time.
            w_len = cache["k"].shape[1]
            slot = jax.lax.rem(cache_index, w_len)
            k = _row_update(cache["k"], k, slot)
            v = _row_update(cache["v"], v, slot)
            b_rows = cache["pos"].shape[0]
            abs_pos = jnp.broadcast_to(
                jnp.asarray(cache_index, jnp.int32).reshape(-1),
                (b_rows,))[:, None]
            slot_vec = jnp.broadcast_to(
                jnp.asarray(slot, jnp.int32).reshape(-1), (b_rows,))
            pos = _row_update(cache["pos"], abs_pos, slot_vec)
            new_cache = {"k": k, "v": v, "pos": pos}
            kv_pos = pos
        elif "pos" in cache:
            # Multi-token ring step: a later chunk token's ring write can
            # evict a slot an *earlier* chunk query still needs (the window
            # trails by W), so attention reads (old ring ∪ chunk) and the
            # ring is only updated for future steps — with the last
            # min(n, W) valid tokens per row.
            w_len = cache["k"].shape[1]
            ci = jnp.broadcast_to(
                jnp.asarray(cache_index, jnp.int32).reshape(-1), (b,))
            n = (jnp.full((b,), sq, jnp.int32) if valid_len is None
                 else jnp.broadcast_to(
                     jnp.asarray(valid_len, jnp.int32), (b,)))
            j = jnp.arange(sq)[None]
            abs_pos = ci[:, None] + j                       # (B, Sq)
            write = (j < n[:, None]) & (j >= (n - w_len)[:, None])
            slots = jax.lax.rem(abs_pos, w_len)
            new_cache = {
                "k": _masked_row_update(cache["k"], k, slots, write),
                "v": _masked_row_update(cache["v"], v, slots, write),
                "pos": _masked_row_update(cache["pos"], abs_pos, slots,
                                          write),
            }
            kv_pos = jnp.concatenate(
                [cache["pos"], jnp.where(j < n[:, None], abs_pos, -1)], 1)
            k = jnp.concatenate([cache["k"], k], axis=1)
            v = jnp.concatenate([cache["v"], v], axis=1)
        elif sq > 1:
            ci = jnp.broadcast_to(
                jnp.asarray(cache_index, jnp.int32).reshape(-1), (b,))
            n = (jnp.full((b,), sq, jnp.int32) if valid_len is None
                 else jnp.broadcast_to(
                     jnp.asarray(valid_len, jnp.int32), (b,)))
            j = jnp.arange(sq)[None]
            tgt = ci[:, None] + j
            write = j < n[:, None]
            k = _masked_row_update(cache["k"], k, tgt, write)
            v = _masked_row_update(cache["v"], v, tgt, write)
            new_cache = {"k": k, "v": v}
        else:
            k = _row_update(cache["k"], k, cache_index)
            v = _row_update(cache["v"], v, cache_index)
            new_cache = {"k": k, "v": v}
    else:
        new_cache = None

    kf = _repeat_kv(k, num_heads)
    vf = _repeat_kv(v, num_heads)

    if cache is not None or sq == 1 or kf.shape[1] <= DENSE_ATTN_MAX_SEQ:
        # cached steps always take the dense path: it is the only one that
        # understands per-row q_offset / ragged kv_pos, and Sq stays small
        # (1 or one prefill chunk) so the score tile is (Sq, S_cache)
        out = _dense_attn(q, kf, vf, causal=causal, window=window,
                          softcap=softcap, q_offset=q_offset, kv_pos=kv_pos)
        out = out.astype(x.dtype)
    else:
        out = _chunked_attn(q, kf, vf, causal=causal, window=window,
                            softcap=softcap)
    out = out.reshape(b, sq, num_heads * head_dim)
    return dense({"w": params["wo"]}, out), new_cache


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, activation, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d_model), 1.0 / math.sqrt(d_ff)
    p = {"w_up": _he(k1, (d_model, d_ff), s_in, dtype),
         "w_down": _he(k2, (d_ff, d_model), s_out, dtype)}
    if activation in ("silu", "geglu"):
        p["w_gate"] = _he(k3, (d_model, d_ff), s_in, dtype)
    return p


def mlp(params, x, activation):
    up = x @ params["w_up"]
    if activation == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * up
    elif activation == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * up
    elif activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(activation)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (grouped top-k, capacity, gather dispatch).
# ---------------------------------------------------------------------------

def init_moe(key, d_model, cfg, dtype):
    ks = jax.random.split(key, 5)
    e, ff = cfg.num_experts, cfg.d_ff_expert
    s_in, s_out = 1.0 / math.sqrt(d_model), 1.0 / math.sqrt(ff)
    p = {
        "router": _he(ks[0], (d_model, e), s_in, jnp.float32),
        "w_gate": _he(ks[1], (e, d_model, ff), s_in, dtype),
        "w_up": _he(ks[2], (e, d_model, ff), s_in, dtype),
        "w_down": _he(ks[3], (e, ff, d_model), s_out, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d_model,
                               ff * cfg.num_shared_experts, "silu", dtype)
        p["shared_gate"] = _he(ks[4], (d_model, 1), s_in, dtype)
    return p


# --- permutation-aware row movement -----------------------------------------
# MoE dispatch is a (partial) permutation of token rows, so BOTH directions
# of every movement can be gathers with precomputed inverse index vectors.
# Plain jnp would autodiff each gather into a scatter-add; on XLA:CPU a row
# scatter lowers to u32 bit-pattern scatters + full-buffer compare/select
# chains (measured: ~3 TB/device on olmoe train_4k), and TPU scatters are
# serialized too. These custom VJPs keep fwd AND bwd gather-only; the only
# scatter left anywhere is the O(T·k) i32 build of the inverse index.

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _perm_take(x, idx, inv_idx, sentinel_zero):
    """y[i] = x[idx[i]]; rows where idx == len(x)-1 read the zero pad row.
    Transpose is the gather via ``inv_idx`` (the inverse permutation)."""
    del inv_idx
    return x[idx]


def _perm_take_fwd(x, idx, inv_idx, sentinel_zero):
    return x[idx], (idx, inv_idx, x.shape[0])


def _perm_take_bwd(sentinel_zero, res, dy):
    del sentinel_zero
    idx, inv_idx, n = res
    # inv_idx covers rows 0..n-2 of x; row n-1 is the shared zero pad row.
    # inv_idx values == len(dy) (the sentinel) read the appended zero row.
    dy_pad = jnp.concatenate(
        [dy, jnp.zeros((1, dy.shape[1]), dy.dtype)], axis=0)
    dx = dy_pad[inv_idx]
    dx = jnp.concatenate(
        [dx, jnp.zeros((1, dx.shape[1]), dx.dtype)], axis=0)
    return dx, None, None


_perm_take.defvjp(_perm_take_fwd, _perm_take_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _replicated_take(x, st, inv_order, k):
    """y[i] = x[st[i]] where every row of x appears exactly k times in st.
    Transpose: dx = dy[inv_order].reshape(T, k, d).sum(1) — a gather, not
    the scatter-add jnp autodiff would emit."""
    del inv_order, k
    return x[st]


def _replicated_take_fwd(x, st, inv_order, k):
    return x[st], (st, inv_order, x.shape[0])


def _replicated_take_bwd(k, res, dy):
    st, inv_order, t = res
    dx = dy[inv_order].reshape(t, k, dy.shape[1]).sum(axis=1)
    return dx, None, None


_replicated_take.defvjp(_replicated_take_fwd, _replicated_take_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _replicated_untake(y, inv_order, st, k):
    """out[t] = sum_j y[inv_order[t*k+j]] — the transpose of
    ``_replicated_take``; its own transpose is the gather via ``st``."""
    del st
    t = inv_order.shape[0] // k
    return y[inv_order].reshape(t, k, y.shape[1]).sum(axis=1)


def _replicated_untake_fwd(y, inv_order, st, k):
    t = inv_order.shape[0] // k
    return (y[inv_order].reshape(t, k, y.shape[1]).sum(axis=1),
            (st,))


def _replicated_untake_bwd(k, res, dout):
    (st,) = res
    return dout[st], None, None


_replicated_untake.defvjp(_replicated_untake_fwd, _replicated_untake_bwd)


def _moe_gather_dispatch(x, params, cfg, weights=None):
    """Sort-based, gather-only dispatch: O(T·k·d) data movement, no
    O(T·E·cap) matmuls, and no row scatters in either direction (see the
    permutation custom-VJPs above).

    x: (T, d) flat tokens -> (out (T, d), aux_loss scalar)
    weights: optional (router, w_gate, w_up, w_down) override — used by the
    shard_map'd expert path, whose weights are the device-local ff slices.
    """
    router, w_gate, w_up, w_down = (
        (params["router"], params["w_gate"], params["w_up"],
         params["w_down"]) if weights is None else weights)
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(t * k * cfg.capacity_factor / e))
    cap = min(cap, t)

    logits = (x.astype(jnp.float32) @ router)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                # (T, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)     # renormalize

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e), axis=0)
    p_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * p_mean)

    flat_e = top_e.reshape(-1)                            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)                 # token of each slot
    flat_p = top_p.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)              # group by expert
    inv_order = jnp.argsort(order, stable=True)           # sorted pos of slot
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    # position within expert = index - start offset of that expert
    counts = jnp.bincount(se, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k) - starts[se]
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e * cap)       # overflow -> trash

    # inverse mapping: which sorted row fills each expert slot (sentinel =
    # T*k -> zero pad row). The only scatter in the block: O(e*cap) i32.
    inv_slot = jnp.full((e * cap + 1,), t * k, jnp.int32).at[dest].set(
        jnp.arange(t * k, dtype=jnp.int32))

    xs = _replicated_take(x, st, inv_order, k)            # (T*k, d) sorted
    xs_z = jnp.concatenate([xs, jnp.zeros((1, d), xs.dtype)], axis=0)
    buf = _perm_take(xs_z, inv_slot[:-1], dest, True)     # (e*cap, d)
    h = buf.reshape(e, cap, d)
    gate = jnp.einsum("ecd,edf->ecf", h, w_gate)
    up = jnp.einsum("ecd,edf->ecf", h, w_up)
    out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                       w_down).reshape(e * cap, d)
    out_e = jnp.concatenate([out_e, jnp.zeros((1, d), out_e.dtype)], 0)

    rows = _perm_take(out_e, dest, inv_slot[:-1], True)   # back to sorted
    contrib = rows * (sp * keep).astype(rows.dtype)[:, None]
    out = _replicated_untake(contrib.astype(x.dtype), inv_order, st, k)
    return out, aux


def _moe_ragged_dispatch(x, router, w_gate, w_up, w_down, cfg):
    """Sorted ragged grouped-matmul dispatch (Megablocks-style, exact MoE).

    Tokens are sorted by expert and multiplied through per-expert weights
    with ``jax.lax.ragged_dot`` — no capacity buffers, no padding slots, no
    token dropping: compute is exactly ``T·k`` rows (the einsum/gather
    dispatches pay a ``capacity_factor`` slack and drop overflow).
    x: (T, d) -> (out (T, d), aux). Weights may be device-local ff slices.
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k

    logits = x.astype(jnp.float32) @ router               # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e), axis=0)
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0))

    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)              # group by expert
    st = jnp.repeat(jnp.arange(t), k)[order]
    sp = top_p.reshape(-1)[order]
    counts = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    xs = x[st]                                            # (T·k, d) sorted
    gate = jax.lax.ragged_dot(xs, w_gate, counts)
    up = jax.lax.ragged_dot(xs, w_up, counts)
    rows = jax.lax.ragged_dot((jax.nn.silu(gate) * up).astype(x.dtype),
                              w_down, counts)             # (T·k, d)
    contrib = rows * sp.astype(rows.dtype)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[st].add(contrib.astype(x.dtype))
    return out, aux


def _moe_einsum_dispatch(x, params, cfg):
    """GShard-style one-hot dispatch (reference / fallback)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(t * k * cfg.capacity_factor / e))
    cap = min(cap, t)

    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e), axis=0)
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0))

    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)     # (T, k, E)
    # position within expert over the flattened (T*k) slot order — the k
    # slots of one token must get distinct positions too
    oh_flat = onehot.reshape(t * k, e)
    pos_flat = jnp.cumsum(oh_flat, axis=0) - 1.0
    pos = jnp.sum(pos_flat * oh_flat, axis=-1).reshape(t, k)
    keep = pos < cap
    disp = (onehot * keep[..., None])                        # (T, k, E)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)     # (T, k, cap)
    dispatch = jnp.einsum("tke,tkc->tec", disp, pos_oh)      # (T, E, cap)
    combine = jnp.einsum("tk,tke,tkc->tec", top_p, disp, pos_oh)

    h = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), x)
    gate = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                       params["w_down"])
    out = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), out_e)
    return out, aux


def _sharded_moe_ok(params, x, cfg, mesh) -> bool:
    """All shard_map divisibility preconditions for the sharded MoE path."""
    if "model" not in mesh.axis_names or "data" not in mesh.axis_names:
        return False
    tp = mesh.shape["model"]
    fs = mesh.shape["data"]
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    b, s, d = x.shape
    ff = params["w_up"].shape[-1]
    return (b % dp == 0 and s % tp == 0 and d % fs == 0 and ff % tp == 0)


def _routed_experts_sharded(params, x, cfg, rules):
    """Routed-expert computation as an explicit shard_map over the mesh.

    Under pjit, the data-dependent scatter/argsort of the dispatch defeats
    the SPMD partitioner: it replicates the whole dispatch across the data
    axis (measured: [global_B, T·k, d/tp] intermediates + 0.5 TB/device of
    all-reduce on olmoe train_4k). Routing is token-local by construction,
    so we do what Megatron does and place the block manually:

      x (B@dp, S@model, d)  --all-gather(model, seq)-->  (B_l, S, d)
      local top-k routing + sort dispatch (device-local, no collectives)
      expert ff slices (E, d, ff/tp): column-parallel gate/up, elementwise
        silu on the slice, row-parallel down  ->  partial (B_l, S, d)
      --psum-scatter(model, seq)-->  (B_l, S@model, d)   [exact: ff sum]

    The only collectives are the Megatron-SP activation all-gather and
    reduce-scatter — identical to what XLA already emits for the *dense*
    MLP under sequence sharding. Expert weights keep their FSDP shard on
    d (all-gathered over the data axes here; the transpose of that gather
    is the grads' reduce-scatter, i.e. ZeRO semantics for free).
    """
    mesh = rules.mesh
    M = "model"
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    from jax.sharding import PartitionSpec as P  # local import, cheap

    def local(x_l, router, wg_l, wu_l, wd_l):
        b_l, s_l, d = x_l.shape
        x_full = jax.lax.all_gather(x_l, M, axis=1, tiled=True)  # (B_l,S,d)
        # FSDP: gather the d-shard of the expert slices over the data axes
        wg = jax.lax.all_gather(wg_l, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wu_l, "data", axis=1, tiled=True)
        wd = jax.lax.all_gather(wd_l, "data", axis=2, tiled=True)
        flat = x_full.reshape(b_l * x_full.shape[1], d)
        # NOTE: _moe_ragged_dispatch (cfg.dispatch="ragged") is the better
        # fit on real TPU hardware, but this container's CPU backend lowers
        # ragged_dot as one dense masked matmul PER GROUP (measured: 30x
        # FLOPs, 1.1 TB/device on olmoe) — so the dry-run default stays on
        # the sorted gather dispatch. See EXPERIMENTS.md §Perf iteration 2.
        if cfg.dispatch == "ragged":
            out, aux = _moe_ragged_dispatch(flat, router, wg, wu, wd, cfg)
        else:
            out, aux = _moe_gather_dispatch(flat, None, cfg,
                                            weights=(router, wg, wu, wd))
        out = out.reshape(b_l, x_full.shape[1], d)
        # row-parallel combine + back to sequence sharding in one collective
        out = jax.lax.psum_scatter(out, M, scatter_dimension=1, tiled=True)
        aux = jax.lax.pmean(aux, dp + (M,))
        return out, aux

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, M, None), P(None, None),
                  P(None, "data", M), P(None, "data", M),
                  P(None, M, "data")),
        out_specs=(P(dp, M, None), P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])


def moe_mlp(params, x, cfg):
    """x (B, S, d) -> (out, aux_loss). Shared experts always active.

    Single-device / no-mesh: routing is *grouped per batch row* (GShard
    groups, vmapped). Under installed sharding rules with a "model" axis,
    the routed experts run as the explicit shard_map block above; the
    dense shared experts stay on the pjit path (XLA partitions plain
    matmuls fine — it is only the dispatch scatter it cannot shard).
    """
    b, s, d = x.shape
    rules = _current_rules()
    if rules is not None and _sharded_moe_ok(params, x, cfg, rules.mesh):
        out, aux = _routed_experts_sharded(params, x, cfg, rules)
    else:
        dispatch = (_moe_gather_dispatch if cfg.dispatch == "gather"
                    else _moe_einsum_dispatch)
        out, aux = jax.vmap(lambda row: dispatch(row, params, cfg))(x)
        aux = jnp.mean(aux)
    flat = x.reshape(b * s, d)
    if cfg.num_shared_experts:
        g = jax.nn.sigmoid(flat @ params["shared_gate"])
        shared = (mlp(params["shared"], flat, "silu") * g).astype(out.dtype)
        out = out + shared.reshape(b, s, d)
    return out, aux
