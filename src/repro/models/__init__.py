"""Model zoo: composable LM covering all assigned architectures."""
