"""Recurrent temporal mixers: RG-LRU (recurrentgemma/Griffin) and RWKV-6.

Both are expressed with parallel-friendly primitives:
  * RG-LRU: elementwise diagonal linear recurrence -> ``associative_scan``.
  * RWKV-6: matrix-valued state with per-channel data-dependent decay ->
    chunked recurrence (intra-chunk matmuls + ``scan`` over chunk states),
    the standard sub-quadratic linear-attention decomposition.

Each mixer also has a single-token ``*_decode_step`` carrying O(1) state —
this is what makes the long_500k decode shape runnable for these families.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _he, dense, rmsnorm, init_rmsnorm


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma).
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru_block(key, d_model, cfg, dtype):
    width = cfg.lru_width or d_model
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d_model)
    # Lambda init so that a = sigmoid(lam)^c is uniform in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (width,), minval=0.9, maxval=0.999)
    lam = jnp.log((u ** (1.0 / _RGLRU_C)) / (1.0 - u ** (1.0 / _RGLRU_C)))
    return {
        "w_x": _he(ks[1], (d_model, width), s, dtype),       # x branch
        "w_gate": _he(ks[2], (d_model, width), s, dtype),    # gelu gate branch
        "w_out": _he(ks[3], (width, d_model),
                     1.0 / math.sqrt(width), dtype),
        "conv_w": _he(ks[4], (cfg.conv_width, width), 0.1, dtype),
        "w_a": _he(ks[5], (width, width), 1.0 / math.sqrt(width), dtype),
        "w_i": _he(ks[6], (width, width), 1.0 / math.sqrt(width), dtype),
        "lam": lam.astype(jnp.float32),
    }


def _causal_conv(x, w, hist=None):
    """Depthwise causal temporal conv. x (B,S,W), w (K,W).

    hist: optional (B, K-1, W) carry of the previous K-1 inputs (chunked
    prefill); defaults to zeros — the left zero-pad of teacher forcing.
    """
    k = w.shape[0]
    if hist is None:
        pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([hist.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):  # small static K (4): unrolled adds, XLA fuses
        out = out + pad[:, i:i + x.shape[1], :] * w[k - 1 - i]
    return out


def _rglru_scan(xt, a, h0=None):
    """h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * xt_t via associative scan.
    xt, a: (B, S, W) f32; h0: optional (B, W) initial state, carried in as
    a virtual leading step (a=1, b=h0) — exact, since combine((1, h0),
    (a_1, b_1)) is the decode-step update."""
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * xt
    if h0 is not None:
        a = jnp.concatenate([jnp.ones_like(h0)[:, None], a], axis=1)
        b = jnp.concatenate([h0[:, None], b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h[:, 1:] if h0 is not None else h


def rglru_block(params, x, cfg, state=None, decode=False, valid_len=None):
    """Griffin recurrent block. x (B,S,d) -> (out, new_state).

    state (decode): dict(conv=(B, K-1, W), h=(B, W)). decode with S > 1 is
    the chunked-prefill path: the scan starts from ``state`` and, when
    ``valid_len`` (B,) is given, positions past a row's valid length are
    identity steps (a=1, input 0) so the carried state is exactly the
    state after that row's last valid token."""
    b, s, _ = x.shape
    single = decode and s == 1 and valid_len is None
    gate = jax.nn.gelu(dense({"w": params["w_gate"]}, x))
    xb = dense({"w": params["w_x"]}, x)
    kw = cfg.conv_width

    if single:
        conv_hist = jnp.concatenate([state["conv"], xb], axis=1)  # (B,K,W)
        # taps: conv_w[j] multiplies x_{t-j}; history is oldest->newest
        xb_c = jnp.einsum("bkw,kw->bw", conv_hist,
                          params["conv_w"][::-1])[:, None]
        new_conv = conv_hist[:, 1:]
    elif decode:
        hist = jnp.concatenate([state["conv"], xb], axis=1)  # (B, K-1+S, W)
        # per-position windows contracted by the same einsum as the
        # single-token step (f32 accumulation), so an S-token decode is
        # bit-identical to S one-token steps — the speculative engine's
        # verify/replay forwards rely on this
        wins = jnp.stack([hist[:, t:t + kw] for t in range(s)],
                         axis=1)                             # (B,S,K,W)
        xb_c = jnp.einsum("bskw,kw->bsw", wins, params["conv_w"][::-1])
        n = (jnp.full((b,), s, jnp.int32) if valid_len is None
             else jnp.asarray(valid_len, jnp.int32))
        # last K-1 inputs ending at each row's final valid token; for
        # n < K-1 this correctly reaches back into the carried history
        new_conv = jax.vmap(
            lambda h, i: jax.lax.dynamic_slice_in_dim(h, i, kw - 1, axis=0)
        )(hist, n)
    else:
        xb_c = _causal_conv(xb, params["conv_w"])
        new_conv = xb[:, -(kw - 1):]

    r = jax.nn.sigmoid(dense({"w": params["w_a"]}, xb_c).astype(jnp.float32))
    i = jax.nn.sigmoid(dense({"w": params["w_i"]}, xb_c).astype(jnp.float32))
    log_a = -_RGLRU_C * r * jax.nn.softplus(-params["lam"])  # log sigmoid^c
    a = jnp.exp(log_a)
    gated = i * xb_c.astype(jnp.float32)

    if single:
        h_prev = state["h"]
        h = a[:, 0] * h_prev + jnp.sqrt(
            jnp.maximum(1.0 - a[:, 0] ** 2, 1e-12)) * gated[:, 0]
        hs = h[:, None]
        new_state = {"conv": new_conv, "h": h}
    elif decode:
        if valid_len is not None:
            valid = (jnp.arange(s)[None] < valid_len[:, None])[..., None]
            a = jnp.where(valid, a, 1.0)
            gated = jnp.where(valid, gated, 0.0)
        hs = _rglru_scan(gated, a, h0=state["h"])
        new_state = {"conv": new_conv, "h": hs[:, -1]}
    else:
        hs = _rglru_scan(gated, a)
        new_state = {"conv": new_conv, "h": hs[:, -1]}

    out = dense({"w": params["w_out"]}, (hs.astype(x.dtype) * gate))
    return out, new_state


def rglru_init_state(batch, cfg, d_model, dtype):
    width = cfg.lru_width or d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, width), dtype),
            "h": jnp.zeros((batch, width), jnp.float32)}


# ---------------------------------------------------------------------------
# RWKV-6 ("Finch") mixer: data-dependent per-channel decay, matrix state.
# ---------------------------------------------------------------------------

def init_rwkv6_block(key, d_model, cfg, dtype):
    ks = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d_model)
    hd = cfg.head_dim
    nh = d_model // hd
    return {
        "w_r": _he(ks[0], (d_model, d_model), s, dtype),
        "w_k": _he(ks[1], (d_model, d_model), s, dtype),
        "w_v": _he(ks[2], (d_model, d_model), s, dtype),
        "w_g": _he(ks[3], (d_model, d_model), s, dtype),
        "w_o": _he(ks[4], (d_model, d_model), s, dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d_model,), -5.0, jnp.float32),
        "decay_A": _he(ks[5], (d_model, cfg.decay_lora), s, dtype),
        "decay_B": _he(ks[6], (cfg.decay_lora, d_model),
                       1.0 / math.sqrt(cfg.decay_lora), dtype),
        "bonus_u": _he(ks[7], (nh, hd), 0.5, jnp.float32),
        # token-shift lerp weights per projection (static in our variant)
        "shift_mix": jax.random.uniform(ks[8], (5, d_model)).astype(dtype),
        "ln_out": init_rmsnorm(d_model, dtype),
    }


def _token_shift(x, prev):
    """(x_{t-1} with x_{-1}=prev) per batch. x (B,S,d), prev (B,1,d)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv6_chunk(r, k, v, w_log, u, state, chunk_len):
    """Chunked WKV recurrence for one head group.

    r,k,v: (B, H, S, hd) f32; w_log: (B, H, S, hd) f32 (log decay, <= 0);
    u: (H, hd) bonus; state: (B, H, hd, hd) f32.
    Returns (out (B,H,S,hd) f32, final state f32).

    All-f32 within the chunk: a mixed bf16/f32 variant was measured WORSE
    on the dry-run (EXPERIMENTS.md §Perf rwkv6 iter 1 — XLA hoists whole-
    buffer converts around the remat'd backward's stacked buffers), and the
    numerically-unbounded decay factors want f32 anyway. The true traffic
    fix on hardware is the VMEM-resident WKV kernel, not dtype games.
    """
    b, h, s, hd = r.shape
    L = min(chunk_len, s)
    assert s % L == 0, (s, L)
    nc = s // L

    def seg(x):
        return x.reshape(b, h, nc, L, hd).transpose(2, 0, 1, 3, 4)

    rs, ks_, vs, ws = seg(r), seg(k), seg(v), seg(w_log)

    def chunk_step(S0, inp):
        rc, kc, vc, wc = inp                      # (B,H,L,hd)
        # inclusive + exclusive within-chunk log decay from one cumsum
        ld = jnp.cumsum(wc, axis=2)
        ld_total = ld[:, :, -1:, :]               # (B,H,1,hd)
        ld_prev = ld - wc                         # exclusive cumsum
        # stabilized factorization (DESIGN.md): exp(ld_prev) <= 1,
        # exp(-ld) clamped — true contribution below e^-60 is zero anyway.
        r2 = rc * jnp.exp(ld_prev)
        k2 = kc * jnp.exp(-jnp.maximum(ld, -60.0))
        att = jnp.einsum("bhld,bhmd->bhlm", r2, k2)
        mask = jnp.tril(jnp.ones((L, L), bool), k=-1)  # strictly causal
        att = jnp.where(mask, att, 0.0)
        # current-token bonus term: u replaces the decay for t == i
        diag = jnp.einsum("bhld,bhld->bhl", rc * u[None, :, None, :], kc)
        out = (jnp.einsum("bhlm,bhmd->bhld", att, vc)
               + jnp.einsum("bhld,bhde->bhle", r2, S0)
               + diag[..., None] * vc)
        # carry state to next chunk; k·exp(ld_total - ld) reuses exp(-ld)
        k3 = k2 * jnp.exp(ld_total)               # <= |k|, stable
        S1 = (jnp.exp(ld_total).transpose(0, 1, 3, 2) * S0
              + jnp.einsum("bhld,bhle->bhde", k3, vc))
        return S1, out

    state_f, outs = jax.lax.scan(chunk_step, state, (rs, ks_, vs, ws))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)
    return out, state_f


def rwkv6_mixer(params, x, cfg, state=None, decode=False, valid_len=None):
    """RWKV-6 time mixer. x (B,S,d) -> (out, new_state).

    state: dict(shift=(B,1,d), wkv=(B,H,hd,hd) f32). decode with S > 1 is
    the chunked-prefill path: the chunk recurrence starts from ``state``
    and, when ``valid_len`` (B,) is given, tokens past a row's valid
    length contribute nothing to the carried state (their k and log-decay
    are zeroed) and the shift carry is that row's last valid token."""
    b, s, d = x.shape
    hd = cfg.head_dim
    nh = d // hd
    single = decode and s == 1 and valid_len is None
    prev = state["shift"] if state is not None else jnp.zeros(
        (b, 1, d), x.dtype)
    xs = _token_shift(x, prev) if not single else prev
    mix = params["shift_mix"]

    def proj(w, i):
        xm = x + (xs - x) * mix[i]
        return xm @ params[w]

    r = proj("w_r", 0).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    k = proj("w_k", 1).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    v = proj("w_v", 2).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(proj("w_g", 3))
    xw = x + (xs - x) * mix[4]
    w_log = -jnp.exp(params["decay_w0"].astype(jnp.float32)
                     + (jnp.tanh(xw @ params["decay_A"]) @ params["decay_B"]
                        ).astype(jnp.float32))
    w_log = w_log.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)

    wkv0 = (state["wkv"] if state is not None
            else jnp.zeros((b, nh, hd, hd), jnp.float32))

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if single:
        # single-token update: o = r.(S + u k^T v); S' = diag(w) S + k^T v
        kv = jnp.einsum("bhsd,bhse->bhde", kf, vf)  # s == 1
        out = (jnp.einsum("bhsd,bhde->bhse", rf, wkv0)
               + jnp.einsum("bhsd,bhde->bhse", rf * params["bonus_u"][None, :, None, :], kv))
        wkv1 = jnp.exp(w_log).transpose(0, 1, 3, 2) * wkv0 + kv
    else:
        if valid_len is not None:
            # ragged chunk: zero k and log-decay past each row's valid
            # length — those tokens then add nothing to the WKV state and
            # decay nothing (exp(0) = 1), freezing it at the last valid
            # token; their own (garbage) outputs are ignored upstream
            vm = (jnp.arange(s)[None] < valid_len[:, None])[:, None, :,
                                                            None]
            kf = jnp.where(vm, kf, 0.0)
            w_log = jnp.where(vm, w_log, 0.0)
        cl = cfg.chunk_len
        if decode and s % min(cl, s) != 0:
            # serve-prefill chunks are small and need not divide
            # chunk_len: run them as one chunk. Training/teacher-forcing
            # keeps the divisibility assert — a silent single-chunk
            # fallback there would be an O(S^2) memory cliff.
            cl = s
        out, wkv1 = _rwkv6_chunk(rf, kf, vf, w_log, params["bonus_u"],
                                 wkv0, cl)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    out = rmsnorm(params["ln_out"], out) * g
    if valid_len is None:
        shift = x[:, -1:]
    else:
        idx = jnp.clip(valid_len - 1, 0, s - 1)
        shift = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    new_state = {"shift": shift, "wkv": wkv1}
    return out @ params["w_o"], new_state


def rwkv6_init_state(batch, cfg, d_model, dtype):
    nh = d_model // cfg.head_dim
    return {"shift": jnp.zeros((batch, 1, d_model), dtype),
            "wkv": jnp.zeros((batch, nh, cfg.head_dim, cfg.head_dim),
                             jnp.float32)}


def init_rwkv_channel_mix(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d_model)
    return {"w_k": _he(ks[0], (d_model, d_ff), s, dtype),
            "w_v": _he(ks[1], (d_ff, d_model), 1.0 / math.sqrt(d_ff), dtype),
            "w_r": _he(ks[2], (d_model, d_model), s, dtype),
            "mix": jax.random.uniform(ks[2], (2, d_model)).astype(dtype)}


def rwkv_channel_mix(params, x, state=None, decode=False, valid_len=None):
    """RWKV channel mixer (squared-relu FFN with receptance gate)."""
    b, s, d = x.shape
    single = decode and s == 1 and valid_len is None
    prev = state if state is not None else jnp.zeros((b, 1, d), x.dtype)
    xs = _token_shift(x, prev) if not single else prev
    xk = x + (xs - x) * params["mix"][0]
    xr = x + (xs - x) * params["mix"][1]
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    out = jax.nn.sigmoid(xr @ params["w_r"]) * (k @ params["w_v"])
    if valid_len is None:
        shift = x[:, -1:]
    else:
        idx = jnp.clip(valid_len - 1, 0, s - 1)
        shift = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    return out, shift
