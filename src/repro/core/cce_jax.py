"""Pure-JAX blockwise CCE — the *analyzable twin* of the Pallas kernels.

Identical algorithm (online log-sum-exp over vocabulary blocks, logit tiles
recomputed in the backward pass, O(N + |V|·D) live memory), expressed with
``lax.scan`` so that:

  * it runs on any backend (the CPU dry-run lowers it; Pallas custom calls
    would be opaque to ``cost_analysis`` and would not lower on CPU), and
  * XLA's cost/memory analysis of the *production train step* sees the true
    FLOP/byte structure of CCE — this is the implementation the distributed
    train step uses under ``pjit``/``shard_map`` on the dry-run, and its HLO
    is what §Roofline measures.

Differences vs. the kernels (documented in DESIGN.md §2): no gradient
filtering / vocabulary sorting — block skipping is real control flow, which
is exactly what Pallas provides on hardware; the scan twin is therefore the
*unfiltered upper bound* on CCE cost (conservative for the roofline).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels.ops import CCEConfig
from repro.kernels.ref import IGNORE_INDEX, apply_softcap

DEFAULT_BLOCK_V = 2048


def _pick_block_v(vocab: int, target: int) -> int:
    """Largest block size <= target that divides vocab (so the block view
    is a free reshape, not a padded copy of the whole classifier); fall
    back to the padded path only when no divisor >= target/2 exists."""
    if vocab <= target:
        return vocab
    for b in range(min(target, vocab), max(target // 2, 127), -1):
        if vocab % b == 0:
            return b
    return target


def _blocks(C, block_v):
    """View (or pad) C as (nV, block_v, D) vocabulary blocks."""
    vocab, d = C.shape
    nv = -(-vocab // block_v)
    pad = nv * block_v - vocab
    if pad:
        C = jnp.concatenate([C, jnp.zeros((pad, d), C.dtype)], axis=0)
    return C.reshape(nv, block_v, d), nv


def _tile(E, cb, softcap):
    """One (N, block_v) logit tile in f32."""
    a = jax.lax.dot_general(E, cb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return apply_softcap(a, softcap)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lse_pick_scan(cfg: CCEConfig, want_sum: bool, E, C, x):
    return _fwd_impl(cfg, want_sum, E, C, x)


def _fwd_impl(cfg, want_sum, E, C, x):
    n_tokens, _ = E.shape
    vocab = C.shape[0]
    block_v = cfg.block_v or _pick_block_v(vocab, DEFAULT_BLOCK_V)
    cb_all, nv = _blocks(C, block_v)
    vstarts = jnp.arange(nv, dtype=jnp.int32) * block_v
    labels = x[:, None]

    def step(carry, inp):
        m, s, p, z = carry
        cb, vstart = inp
        a = _tile(E, cb, cfg.softcap)
        col = vstart + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        if want_sum:
            # per-token sum of (capped) logits — accumulated pre the -inf
            # mask (padded columns contribute 0, not -inf).
            z = z + jnp.sum(jnp.where(col < vocab, a, 0.0), axis=1)
        a = jnp.where(col < vocab, a, -jnp.inf)
        p = p + jnp.sum(jnp.where(col == labels, a, 0.0), axis=1)
        bmax = jnp.max(a, axis=1)
        m_new = jnp.maximum(m, bmax)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        s = s * jnp.exp(m - m_safe) + jnp.sum(jnp.exp(a - m_safe[:, None]), 1)
        return (m_new, s, p, z), None

    # Derive the init from E *and* C so it inherits both varying-axis types
    # when this runs inside shard_map (vocab-parallel CCE: E varies over the
    # token axes, C over the vocab axis) — plain constants would not.
    zero_n = (E[:, 0] * 0 + C[0, 0] * 0).astype(jnp.float32)
    init = (zero_n - jnp.inf, zero_n, zero_n, zero_n)
    (m, s, p, z), _ = jax.lax.scan(step, init, (cb_all, vstarts))
    if want_sum:
        return m + jnp.log(s), p, z
    return m + jnp.log(s), p


def _vjp_fwd(cfg, want_sum, E, C, x):
    outs = _fwd_impl(cfg, want_sum, E, C, x)
    return outs, (E, C, x, outs[0])


def _vjp_bwd(cfg, want_sum, residuals, cotangents):
    E, C, x, lse = residuals
    g_lse, g_pick = cotangents[0], cotangents[1]
    gz = cotangents[2].astype(jnp.float32)[:, None] if want_sum else None
    n_tokens, d = E.shape
    vocab = C.shape[0]
    block_v = cfg.block_v or _pick_block_v(vocab, DEFAULT_BLOCK_V)
    cb_all, nv = _blocks(C, block_v)
    vstarts = jnp.arange(nv, dtype=jnp.int32) * block_v
    labels = x[:, None]
    gl = g_lse.astype(jnp.float32)[:, None]
    gp = g_pick.astype(jnp.float32)[:, None]

    def step(de_acc, inp):
        cb, vstart = inp
        a = jax.lax.dot_general(E, cb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if cfg.softcap is not None:
            t = jnp.tanh(a / cfg.softcap)
            a_capped = cfg.softcap * t
            dcap = 1.0 - t * t
        else:
            a_capped, dcap = a, None
        col = vstart + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        valid = col < vocab
        s = jnp.where(valid, jnp.exp(a_capped - lse[:, None]), 0.0)
        onehot = jnp.where((col == labels) & valid, 1.0, 0.0)
        dz = gl * s + gp * onehot
        if gz is not None:
            dz = dz + gz * jnp.where(valid, 1.0, 0.0)
        if dcap is not None:
            dz = dz * dcap
        de_acc = de_acc + jnp.dot(dz, cb.astype(jnp.float32),
                                  preferred_element_type=jnp.float32)
        dcb = jax.lax.dot_general(dz, E, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        return de_acc, dcb

    # E+C-derived init: see _fwd_impl (shard_map varying-axis types).
    de, dcb = jax.lax.scan(step, (E * 0 + C[0, 0] * 0).astype(jnp.float32),
                           (cb_all, vstarts))
    dc = dcb.reshape(nv * block_v, d)[:vocab]
    return de.astype(E.dtype), dc.astype(C.dtype), None


_lse_pick_scan.defvjp(_vjp_fwd, _vjp_bwd)


def _flatten_call(E, C, x, cfg, want_sum):
    orig_shape = x.shape
    if E.ndim == 3:
        E = E.reshape(-1, E.shape[-1])
        x = x.reshape(-1)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x).astype(jnp.int32)
    outs = _lse_pick_scan(cfg, want_sum, E, C, safe_x)
    return tuple(o.reshape(orig_shape) for o in outs)


def lse_and_pick_jax(E, C, x, cfg: CCEConfig | None = None, **overrides):
    """(lse, pick) via the portable scan implementation (shapes like x)."""
    cfg = dataclasses.replace(cfg or CCEConfig(), **overrides)
    return _flatten_call(E, C, x, cfg, False)


def lse_pick_sum_jax(E, C, x, cfg: CCEConfig | None = None, **overrides):
    """(lse, pick, sum_logits) via the portable scan twin — same third
    output as :func:`repro.kernels.ops.lse_pick_sum_pallas`."""
    cfg = dataclasses.replace(cfg or CCEConfig(), **overrides)
    return _flatten_call(E, C, x, cfg, True)


def linear_cross_entropy_jax(E, C, x, cfg: CCEConfig | None = None,
                             **overrides):
    """Per-token NLL (shape of x) with CCE memory behaviour, pure JAX."""
    lse, pick = lse_and_pick_jax(E, C, x, cfg, **overrides)
    return jnp.where(x == IGNORE_INDEX, 0.0, lse - pick)
