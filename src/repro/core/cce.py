"""Legacy CCE API — thin deprecated shims over :func:`repro.core.cross_entropy`.

``linear_cross_entropy(E, C, x, impl=...)`` predates the backend registry;
new code should call :func:`repro.core.cross_entropy` (one entry point for
every loss, backend, and — via ``mesh=`` — the vocab-parallel combine) and
:func:`repro.backends.resolve` for dispatch. Backends are registered in
:mod:`repro.backends` (``cce``, ``cce_jax``, ``dense``, ``chunked``,
``liger``; see ``python -m repro.backends`` for the capability matrix).

Reductions: "none" (per-token), "mean" (over non-ignored tokens), "sum".

NLL is only one member of the loss family built on the ``lse_and_pick``
primitive: see :mod:`repro.losses` for the registry of memory-efficient
vocabulary losses (z-loss, focal, label smoothing, per-token weighting,
sequence scoring) — all of which inherit CCE's O(N·D + V·D) memory class.

Kernel-level knobs (block sizes, gradient filtering, the fused single-pass
backward and its forward-emitted block-sparsity map — DESIGN.md §7) travel
in :class:`CCEConfig` (re-exported here from ``repro.kernels.ops``); every
entry point below and :func:`repro.core.cross_entropy` accept ``cfg=``.
"""

from __future__ import annotations

import warnings

from repro.kernels import ops as kernel_ops

CCEConfig = kernel_ops.CCEConfig


def _impls():
    from repro import backends
    return ("auto",) + tuple(backends.list_backends())


def __getattr__(name):
    if name == "IMPLS":   # registry-derived, computed lazily
        return _impls()
    raise AttributeError(name)


def _reduce(nll, x, reduction):
    """Deprecated alias of the canonical :func:`repro.losses.reduce_loss`."""
    from repro.losses.base import reduce_loss
    return reduce_loss(nll, x, reduction)


def linear_cross_entropy(E, C, x, *, impl: str = "auto",
                         softcap: float | None = None,
                         reduction: str = "none",
                         cfg: CCEConfig | None = None,
                         num_chunks: int = 8):
    """Deprecated shim: plain-NLL ``cross_entropy``.

    E: (..., D) embeddings, C: (V, D) classifier, x: (...) int labels
    (IGNORE_INDEX positions get loss 0 / no gradient). Use
    ``repro.core.cross_entropy`` — same semantics, plus ``loss=`` and
    ``mesh=``.
    """
    warnings.warn("linear_cross_entropy is deprecated; use "
                  "repro.core.cross_entropy(E, C, x, impl=..., ...)",
                  DeprecationWarning, stacklevel=2)
    from repro.core.api import cross_entropy
    return cross_entropy(E, C, x, impl=impl, softcap=softcap,
                         reduction=reduction, cfg=cfg,
                         num_chunks=num_chunks)


def lse_and_pick(E, C, x, *, impl: str = "auto",
                 cfg: CCEConfig | None = None,
                 with_sum_logits: bool = False):
    """The (lse, pick[, sum_logits]) primitive — building block for the
    loss family in :mod:`repro.losses` and the vocab-parallel combination.

    ``with_sum_logits=True`` requests the third output (per-token sum of
    softcapped logits over the vocabulary, e.g. for label smoothing); it is
    a static flag, so the two-output path compiles no dead sum compute.
    ``impl="dense"`` materializes the (N, V) logit matrix — the O(N·V)
    reference twin the loss tests gradcheck against.

    Thin wrapper over ``repro.backends.resolve(impl).lse_pick(...)``.
    """
    from repro import backends
    be = backends.resolve(impl, requirements=backends.Requirements(
        custom_cotangents=True, sum_logits=with_sum_logits))
    return be.lse_pick(E, C, x, backends.resolve_config(cfg),
                       with_sum_logits=with_sum_logits)
