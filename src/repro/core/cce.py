"""Public CCE API — the paper's contribution as one composable JAX op.

``linear_cross_entropy(E, C, x, impl=...)`` dispatches between:

  impl="cce"        Pallas TPU kernels (interpret-mode on CPU) — the paper's
                    method, with gradient filtering + vocab sorting.
  impl="cce_jax"    portable lax.scan twin (same algorithm & memory class;
                    what the distributed train step lowers on the dry-run).
  impl="dense"      paper "Baseline"/"torch.compile" row (O(N·V) memory).
  impl="chunked"    paper "Torch Tune" row (O(N/K·V)).
  impl="liger"      paper "Liger Kernels" row (scalar loss, fwd-computed
                    grads, O(N·D + V·D)).
  impl="auto"       "cce" on TPU, "cce_jax" elsewhere.

Reductions: "none" (per-token), "mean" (over non-ignored tokens), "sum".

NLL is only one member of the loss family built on the ``lse_and_pick``
primitive: see :mod:`repro.losses` for the registry of memory-efficient
vocabulary losses (z-loss, focal, label smoothing, per-token weighting,
sequence scoring) — ``repro.losses.get_loss(name, **kw)`` — all of which
inherit CCE's O(N·D + V·D) memory class through this module.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import baselines, cce_jax
from repro.kernels import ops as kernel_ops
from repro.kernels.ref import IGNORE_INDEX

CCEConfig = kernel_ops.CCEConfig

IMPLS = ("auto", "cce", "cce_jax", "dense", "chunked", "liger")


def _reduce(nll, x, reduction):
    if reduction == "none":
        return nll
    valid = (x != IGNORE_INDEX)
    total = jnp.sum(nll)
    if reduction == "sum":
        return total
    if reduction == "mean":
        return total / jnp.maximum(jnp.sum(valid), 1).astype(nll.dtype)
    raise ValueError(f"unknown reduction {reduction!r}")


def linear_cross_entropy(E, C, x, *, impl: str = "auto",
                         softcap: float | None = None,
                         reduction: str = "none",
                         cfg: CCEConfig | None = None,
                         num_chunks: int = 8):
    """Cross-entropy of next-token logits ``softcap(E @ C.T)`` vs labels x.

    E: (..., D) embeddings, C: (V, D) classifier, x: (...) int labels
    (IGNORE_INDEX positions get loss 0 / no gradient).
    """
    if impl == "auto":
        import jax
        impl = "cce" if jax.default_backend() == "tpu" else "cce_jax"
    if cfg is None:
        cfg = CCEConfig(softcap=softcap)
    elif softcap is not None and cfg.softcap != softcap:
        import dataclasses
        cfg = dataclasses.replace(cfg, softcap=softcap)

    if impl == "cce":
        nll = kernel_ops.linear_cross_entropy_pallas(E, C, x, cfg)
    elif impl == "cce_jax":
        nll = cce_jax.linear_cross_entropy_jax(E, C, x, cfg)
    elif impl == "dense":
        nll = baselines.dense_linear_cross_entropy(E, C, x, cfg.softcap)
    elif impl == "chunked":
        nll = baselines.chunked_linear_cross_entropy(
            E, C, x, cfg.softcap, num_chunks)
    elif impl == "liger":
        if reduction != "mean":
            raise ValueError("liger-style computes grads in the forward and "
                             "therefore owns the reduction; use "
                             "reduction='mean' (the paper's composability "
                             "caveat, §2).")
        return baselines.liger_style_cross_entropy(
            E, C, x, cfg.softcap, num_chunks)
    else:
        raise ValueError(f"unknown impl {impl!r}; one of {IMPLS}")
    return _reduce(nll, x, reduction)


def lse_and_pick(E, C, x, *, impl: str = "auto",
                 cfg: CCEConfig | None = None,
                 with_sum_logits: bool = False):
    """The (lse, pick[, sum_logits]) primitive — building block for the
    loss family in :mod:`repro.losses` and the vocab-parallel combination.

    ``with_sum_logits=True`` requests the third output (per-token sum of
    softcapped logits over the vocabulary, e.g. for label smoothing); it is
    a static flag, so the two-output path compiles no dead sum compute.
    ``impl="dense"`` materializes the (N, V) logit matrix — the O(N·V)
    reference twin the loss tests gradcheck against.
    """
    if impl == "auto":
        import jax
        impl = "cce" if jax.default_backend() == "tpu" else "cce_jax"
    cfg = cfg or CCEConfig()
    if impl == "cce":
        if with_sum_logits:
            return kernel_ops.lse_pick_sum_pallas(E, C, x, cfg)
        return kernel_ops.lse_and_pick_pallas(E, C, x, cfg)
    if impl == "cce_jax":
        if with_sum_logits:
            return cce_jax.lse_pick_sum_jax(E, C, x, cfg)
        return cce_jax.lse_and_pick_jax(E, C, x, cfg)
    if impl == "dense":
        return baselines.dense_lse_pick(E, C, x, cfg.softcap,
                                        with_sum=with_sum_logits)
    raise ValueError(f"lse_and_pick supports impl in ('cce', 'cce_jax', "
                     f"'dense'), got {impl!r}")
