"""Baseline cross-entropy implementations the paper compares against.

Each mirrors a row of the paper's Table 1:

  * :func:`dense_linear_cross_entropy`   — "Baseline"/"torch.compile": the
    full (N, V) logit matrix is materialized (XLA fuses what it can, like
    torch.compile does; the O(N·V) residual for the backward remains).
  * :func:`chunked_linear_cross_entropy` — "Torch Tune (8 chunks)": the token
    axis is split into K chunks; each chunk computes a dense loss under
    ``jax.checkpoint`` so the backward recomputes that chunk's logits.
    Peak live logits: O(N/K · V).
  * :func:`liger_style_cross_entropy`    — "Liger Kernels": chunked, and the
    gradient is computed *during the forward* and stored (O(N·D + V·D)),
    so the op must own the loss reduction (mean over valid tokens) — the
    composability restriction the paper points out. Returns a scalar.

All support softcap and IGNORE_INDEX semantics, matching the CCE paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ref import IGNORE_INDEX, apply_softcap


def _dense_nll(E, C, x, softcap):
    logits = jax.lax.dot_general(E, C, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits = apply_softcap(logits, softcap)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    pick = jnp.take_along_axis(logits, safe_x[:, None], axis=-1)[:, 0]
    return jnp.where(x == IGNORE_INDEX, 0.0, lse - pick)


def dense_linear_cross_entropy(E, C, x, softcap=None):
    """Per-token NLL, materializing the full logit matrix (paper Baseline)."""
    orig_shape = x.shape
    if E.ndim == 3:
        E, x = E.reshape(-1, E.shape[-1]), x.reshape(-1)
    return _dense_nll(E, C, x, softcap).reshape(orig_shape)


def dense_lse_pick(E, C, x, softcap=None, with_sum=False):
    """(lse, pick[, sum_logits]) from the materialized (N, V) logit matrix.

    The O(N·V) reference twin of the CCE primitive: differentiable by plain
    autodiff, used to gradcheck every loss in :mod:`repro.losses` and as the
    ``impl="dense"`` dispatch of ``repro.core.lse_and_pick``.
    """
    orig_shape = x.shape
    if E.ndim == 3:
        E, x = E.reshape(-1, E.shape[-1]), x.reshape(-1)
    logits = jax.lax.dot_general(E, C, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits = apply_softcap(logits, softcap)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe_x = jnp.where(x == IGNORE_INDEX, 0, x)
    pick = jnp.take_along_axis(logits, safe_x[:, None], axis=-1)[:, 0]
    if not with_sum:
        return lse.reshape(orig_shape), pick.reshape(orig_shape)
    zsum = jnp.sum(logits, axis=-1)
    return (lse.reshape(orig_shape), pick.reshape(orig_shape),
            zsum.reshape(orig_shape))


def chunked_linear_cross_entropy(E, C, x, softcap=None, num_chunks: int = 8):
    """Per-token NLL in N-chunks (Torch-Tune style). ``jax.checkpoint`` keeps
    the backward's live logits to one chunk as well."""
    orig_shape = x.shape
    if E.ndim == 3:
        E, x = E.reshape(-1, E.shape[-1]), x.reshape(-1)
    n = E.shape[0]
    chunk = -(-n // num_chunks)
    pad = chunk * num_chunks - n
    if pad:
        E = jnp.concatenate([E, jnp.zeros((pad, E.shape[1]), E.dtype)])
        x = jnp.concatenate([x, jnp.full((pad,), IGNORE_INDEX, x.dtype)])
    Eb = E.reshape(num_chunks, chunk, -1)
    xb = x.reshape(num_chunks, chunk)

    f = jax.checkpoint(functools.partial(_dense_nll, softcap=softcap))
    nll = jax.lax.map(lambda args: f(args[0], C, args[1]), (Eb, xb))
    return nll.reshape(-1)[:n].reshape(orig_shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _liger_loss(E, C, x, softcap, num_chunks):
    loss, _, _ = _liger_fwd_impl(E, C, x, softcap, num_chunks)
    return loss


def _liger_fwd_impl(E, C, x, softcap, num_chunks):
    """Computes mean NLL and its (unscaled) grads chunk-by-chunk in one pass."""
    n, d = E.shape
    chunk = -(-n // num_chunks)
    pad = chunk * num_chunks - n
    if pad:
        E = jnp.concatenate([E, jnp.zeros((pad, d), E.dtype)])
        x = jnp.concatenate([x, jnp.full((pad,), IGNORE_INDEX, x.dtype)])
    Eb = E.reshape(num_chunks, chunk, d)
    xb = x.reshape(num_chunks, chunk)
    n_valid = jnp.maximum(jnp.sum(x != IGNORE_INDEX), 1).astype(jnp.float32)

    def step(dc_acc, inp):
        e, xc = inp
        logits = jax.lax.dot_general(e, C, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        if softcap is not None:
            t = jnp.tanh(logits / softcap)
            logits_c, dcap = softcap * t, 1.0 - t * t
        else:
            logits_c, dcap = logits, None
        lse = jax.scipy.special.logsumexp(logits_c, axis=-1)
        safe = jnp.where(xc == IGNORE_INDEX, 0, xc)
        pick = jnp.take_along_axis(logits_c, safe[:, None], -1)[:, 0]
        valid = (xc != IGNORE_INDEX)
        nll_sum = jnp.sum(jnp.where(valid, lse - pick, 0.0))
        # grad of mean-NLL w.r.t. raw logits for this chunk
        s = jnp.exp(logits_c - lse[:, None])
        onehot = jax.nn.one_hot(safe, C.shape[0], dtype=jnp.float32)
        dz = (s - onehot) * (valid[:, None] / n_valid)
        if dcap is not None:
            dz = dz * dcap
        de = jnp.dot(dz, C.astype(jnp.float32)).astype(e.dtype)
        dc_acc = dc_acc + jax.lax.dot_general(
            dz, e, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return dc_acc, (nll_sum, de)

    dc, (nll_sums, de) = jax.lax.scan(
        step, jnp.zeros(C.shape, jnp.float32), (Eb, xb))
    loss = jnp.sum(nll_sums) / n_valid
    return loss, de.reshape(-1, d)[:n], dc.astype(C.dtype)


def _liger_vjp_fwd(E, C, x, softcap, num_chunks):
    loss, de, dc = _liger_fwd_impl(E, C, x, softcap, num_chunks)
    return loss, (de, dc)


def _liger_vjp_bwd(softcap, num_chunks, residuals, g):
    de, dc = residuals
    # g (f32 scalar) * bf16 residual promotes to f32; cotangents must keep
    # the primal dtype or custom_vjp rejects them on bf16 models.
    return ((g * de).astype(de.dtype), (g * dc).astype(dc.dtype), None)


_liger_loss.defvjp(_liger_vjp_fwd, _liger_vjp_bwd)


def liger_style_cross_entropy(E, C, x, softcap=None, num_chunks: int = 8):
    """Scalar mean NLL; gradient precomputed during forward (Liger style)."""
    if E.ndim == 3:
        E, x = E.reshape(-1, E.shape[-1]), x.reshape(-1)
    return _liger_loss(E, C, x, softcap, num_chunks)
