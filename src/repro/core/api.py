"""One loss entry point: ``cross_entropy(E, C, x, ..., mesh=...)``.

The public surface of the whole repo's loss stack. One call expresses:

  * *which loss* — ``loss=`` takes a :mod:`repro.losses` registry name, a
    :class:`~repro.losses.LossConfig`, or a live
    :class:`~repro.losses.VocabLoss` (default: plain NLL, the paper's
    loss);
  * *which realization* — ``impl=`` names a :mod:`repro.backends` entry or
    ``"auto"``; resolution is capability-driven, so asking an NLL-only
    baseline for a registry loss (or liger for a per-token reduction)
    raises an error that lists the backends which *can* do it;
  * *where it runs* — ``mesh=None`` is single-device; passing a mesh
    routes the *same resolved backend* through the vocab-parallel
    shard_map combine (classifier sharded over ``vocab_axis``, tokens
    over ``token_axes``), so distribution is a property of the call, not
    a different function. Every registry loss works sharded or local
    through this one path.

``linear_cross_entropy`` and ``vocab_parallel_cross_entropy`` remain as
thin deprecated shims over this function.
"""

from __future__ import annotations

from repro.kernels.ops import CCEConfig


def _resolve_loss(loss):
    # lazy: repro.losses imports repro.backends, which imports repro.core
    from repro.losses import base as losses_base
    if loss is None:
        return losses_base.get_loss("nll")
    if isinstance(loss, str):
        return losses_base.get_loss(loss)
    if isinstance(loss, losses_base.LossConfig):
        return loss.build()
    if isinstance(loss, losses_base.VocabLoss):
        return loss
    raise TypeError(
        f"loss must be a registry name, LossConfig, or VocabLoss; got "
        f"{type(loss).__name__}")


def cross_entropy(E, C, x, *, loss=None, impl: str = "auto",
                  mesh=None, vocab_axis: str = "model",
                  token_axes=("data",),
                  reduction: str = "none", weights=None,
                  softcap: float | None = None,
                  cfg: CCEConfig | None = None, num_chunks: int = 8):
    """Cross-entropy-family loss of logits ``softcap(E @ C.T)`` vs labels.

    E: (..., D) embeddings; C: (V, D) classifier; x: (...) int labels
    (``IGNORE_INDEX`` positions get loss 0 / no gradient).

    loss: registry name / LossConfig / VocabLoss instance (default "nll").
    impl: backend name from ``repro.backends.list_backends()`` or "auto".
    mesh: optional ``jax.sharding.Mesh``; when given, C is expected
        sharded over ``vocab_axis`` and tokens over ``token_axes``, and
        the resolved backend runs per-shard under the O(N)-wire
        vocab-parallel combine.
    reduction: "none" (per-token) | "mean" (over non-ignored tokens,
        weight-normalized when ``weights`` is given) | "sum".
    weights: optional per-token weights (shape of x).
    num_chunks: chunk count for the chunked/liger baselines.
    """
    from repro import backends
    from repro.losses.base import reduce_loss
    from repro.losses.zoo import NLL

    loss_obj = _resolve_loss(loss)
    cfg = backends.resolve_config(cfg, softcap)

    # Plain unweighted local NLL is the one case the NLL-only baselines
    # (chunked, liger) can serve; everything else needs the differentiable
    # lse_pick primitive.
    needs_primitive = (not isinstance(loss_obj, NLL)
                       or weights is not None or mesh is not None)
    req = backends.Requirements(
        custom_cotangents=needs_primitive,
        sum_logits=loss_obj.needs_sum_logits,
        mesh=mesh is not None,
        reduction=reduction)
    be = backends.resolve(impl, requirements=req)

    if be.owns_reduction:                       # liger: scalar mean NLL
        return be.reduced_loss(E, C, x, cfg, num_chunks=num_chunks)
    if not be.supports_custom_cotangents:       # chunked: per-token NLL
        return reduce_loss(be.nll(E, C, x, cfg, num_chunks=num_chunks),
                           x, reduction)
    return loss_obj(E, C, x, backend=be, cfg=cfg, reduction=reduction,
                    weights=weights, mesh=mesh, vocab_axis=vocab_axis,
                    token_axes=token_axes)
