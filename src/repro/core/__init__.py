"""Core CCE API — the paper's primary contribution as composable JAX ops.

The loss *family* built on these ops lives in :mod:`repro.losses`."""

from repro.core.cce import (  # noqa: F401
    CCEConfig,
    IMPLS,
    linear_cross_entropy,
    lse_and_pick,
)
from repro.core.vocab_parallel import (  # noqa: F401
    vocab_parallel_cross_entropy,
    vocab_parallel_lse_pick,
)
from repro.kernels.ref import IGNORE_INDEX  # noqa: F401
