"""Core CCE API — the paper's primary contribution as composable JAX ops.

One entry point: :func:`cross_entropy` (any :mod:`repro.losses` entry, any
:mod:`repro.backends` realization, local or vocab-parallel via ``mesh=``).
``linear_cross_entropy`` / ``vocab_parallel_cross_entropy`` are deprecated
shims kept for older callers."""

from repro.core.api import cross_entropy  # noqa: F401
from repro.core.cce import (  # noqa: F401
    CCEConfig,
    linear_cross_entropy,
    lse_and_pick,
)
from repro.core.vocab_parallel import (  # noqa: F401
    vocab_parallel_cross_entropy,
    vocab_parallel_lse_pick,
)
from repro.kernels.ref import IGNORE_INDEX  # noqa: F401


def __getattr__(name):
    if name == "IMPLS":   # legacy alias; derived from the backend registry
        from repro.core import cce
        return cce.IMPLS
    raise AttributeError(name)
