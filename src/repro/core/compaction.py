"""Removing ignored tokens before the loss (paper Appendix B).

Every implementation the paper surveys first computes logits/loss for
ignored positions (padding, system prompts, ...) and then zeroes them.
Compacting valid tokens to the front and slicing to a static ``capacity``
skips that work entirely, with bit-identical loss/gradients as long as
``capacity >= number of valid tokens`` (the caller owns that bound — under
jit shapes must be static, so dynamic token counts are not expressible).

The gather is differentiable: dE scatters back to the original rows, with
exact zeros at ignored positions.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ref import IGNORE_INDEX


def compact_valid_tokens(E, x, capacity: int):
    """(E2 (capacity, D), x2 (capacity,)) with valid tokens first.

    Overflow beyond ``capacity`` is dropped (choose capacity with
    headroom); padding slots carry IGNORE_INDEX labels so downstream loss
    masks them to zero.
    """
    n = x.shape[0]
    valid = x != IGNORE_INDEX
    # stable ordering: valid tokens keep their relative order
    order = jnp.argsort(jnp.where(valid, 0, 1), stable=True)
    idx = order[:capacity]
    E2 = jnp.take(E, idx, axis=0)
    x2 = jnp.take(x, idx, axis=0)
    in_range = jnp.arange(capacity) < jnp.sum(valid)
    x2 = jnp.where(in_range, x2, IGNORE_INDEX)
    return E2, x2
