"""Distributed CCE: vocabulary(tensor)-parallel + sequence/data-parallel.

Beyond-paper extension (DESIGN.md §3): the paper evaluates CCE on a single
GPU with a replicated classifier. At pod scale the classifier C (|V|×D, up
to 256k×4k ≈ 2 GB bf16) is sharded over the ``model`` mesh axis. Each shard
computes a *local* (lse, pick) over its vocabulary slice with the CCE
primitive; the global combine needs only O(N) collectives:

    pick  = psum_over_shards(local pick masked to the owning shard)
    lse   = m + log( psum_over_shards( exp(local_lse - m) ) ),
    m     = pmax_over_shards(local_lse)            (stop-gradient: LSE is
                                                    mathematically m-free)
    sum_logits = psum_over_shards(local sum_logits)   (optional third output
                                                       — plain sum, so the
                                                       combine is one psum)

Compare: a Megatron-style vocab-parallel CE materializes the (N, |V|/tp)
logit shard in HBM; CCE never does. Wire bytes stay O(N) either way — CCE
removes the O(N·|V|/tp) *memory* term, which is what limits batch size.
(The Megatron baseline is still expressible: ``backend="dense"`` runs the
materialized per-shard lse_pick under the same combine.)

Tokens are sharded over the data axes (sequence/data parallel): the loss is
token-local, so composing the two costs nothing extra. Autodiff flows
through psum/pmax, and the local primitive's custom VJP receives exactly the
per-shard cotangents (softmax weights of the global LSE) — no bespoke
backward is needed. Because the whole loss family in :mod:`repro.losses` is
a function of the global ``(lse, pick[, sum_logits])``, every registry loss
distributes through this module unchanged — callers reach it through
``repro.core.cross_entropy(..., mesh=...)``, which routes whichever
:mod:`repro.backends` entry it resolved into this combine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels.ops import CCEConfig


def _local_lse_pick(E_l, C_l, x_l, vocab_axis, token_axes, backend, cfg,
                    with_sum):
    """Per-device body: local CCE over this device's vocab shard, computed
    by whichever registered backend the caller resolved."""
    if backend.shard_map_check_vma:
        # E/x arrive replicated over the vocab axis and C replicated over the
        # token axes; mark them device-varying so the transpose of these
        # casts (a psum over the corresponding shards) yields the correct
        # global gradients — each device contributes its (token-slice ×
        # vocab-slice) partial of dE and dC. Under check_vma=False (the
        # Pallas-interpret path) shard_map's pessimistic transpose inserts
        # the same psums itself.
        E_l = compat.pcast_varying(E_l, (vocab_axis,))
        x_l = compat.pcast_varying(x_l, (vocab_axis,))
        C_l = compat.pcast_varying(C_l, tuple(token_axes))
    idx = jax.lax.axis_index(vocab_axis)
    v_local = C_l.shape[0]
    lo = idx * v_local
    in_range = (x_l >= lo) & (x_l < lo + v_local)
    x_loc = jnp.where(in_range, x_l - lo, 0)
    out = backend.lse_pick(E_l, C_l, x_loc, cfg, with_sum_logits=with_sum)
    lse_l, pick_l = out[0], out[1]

    pick = jax.lax.psum(jnp.where(in_range, pick_l, 0.0), vocab_axis)
    # stop_gradient *before* pmax (no diff rule) — LSE is mathematically
    # independent of the max-shift m, so this is exact.
    m = jax.lax.pmax(jax.lax.stop_gradient(lse_l), vocab_axis)
    lse = m + jnp.log(jax.lax.psum(jnp.exp(lse_l - m), vocab_axis))
    if not with_sum:
        return lse, pick
    # sum of logits is linear over the vocab partition: one psum.
    zsum = jax.lax.psum(out[2], vocab_axis)
    return lse, pick, zsum


def vocab_parallel_lse_pick(E, C, x, *, mesh, vocab_axis: str = "model",
                            token_axes=("data",), impl: str = "auto",
                            backend=None, cfg: CCEConfig | None = None,
                            with_sum_logits: bool = False):
    """(lse, pick[, sum_logits]) with C sharded over ``vocab_axis`` and
    tokens sharded over ``token_axes``. E: (N, D), C: (V, D), x: (N,).

    ``backend`` is a resolved :class:`repro.backends.Backend` (or pass
    ``impl`` to resolve one here); the same backend that would run locally
    runs per-shard.
    """
    from repro import backends as backends_mod
    if backend is None:
        backend = backends_mod.resolve(
            impl, requirements=backends_mod.Requirements(
                custom_cotangents=True, sum_logits=with_sum_logits,
                mesh=True))
    cfg = backends_mod.resolve_config(cfg)
    token_spec = P(tuple(token_axes))

    # check_vma must be off for the Pallas path (backend attribute): in
    # interpret mode (CPU) the kernel body is evaluated as JAX ops whose
    # internal iotas/constants are unvarying, which trips the checker;
    # shard_map then inserts the replication-transpose psums pessimistically,
    # so gradients match.
    def f(E_l, C_l, x_l):
        return _local_lse_pick(E_l, C_l, x_l, vocab_axis, token_axes,
                               backend, cfg, with_sum_logits)

    n_out = 3 if with_sum_logits else 2
    return compat.shard_map(
        f, mesh=mesh,
        in_specs=(P(tuple(token_axes), None), P(vocab_axis, None), token_spec),
        out_specs=(token_spec,) * n_out,
        check_vma=backend.shard_map_check_vma,
    )(E, C, x)


def vocab_parallel_cross_entropy(E, C, x, *, mesh, vocab_axis: str = "model",
                                 token_axes=("data",), impl: str = "auto",
                                 cfg: CCEConfig | None = None,
                                 reduction: str = "none"):
    """Deprecated shim: ``cross_entropy(..., mesh=mesh)`` — distribution is
    now a property of the call, not a different function."""
    import warnings
    warnings.warn("vocab_parallel_cross_entropy is deprecated; use "
                  "repro.core.cross_entropy(E, C, x, mesh=mesh, ...)",
                  DeprecationWarning, stacklevel=2)
    from repro.core.api import cross_entropy
    return cross_entropy(E, C, x, impl=impl, mesh=mesh,
                         vocab_axis=vocab_axis, token_axes=token_axes,
                         cfg=cfg, reduction=reduction)
